//! Artifact runtime: the parameterized kernel suite behind the compress /
//! SELECT hot paths.
//!
//! Every artifact dispatch is keyed by an [`EntryKey`]
//! `(kind, shard_w, n_traits)` and canonicalized through a
//! [`ShapePolicy`] (a small ladder of canonical shard widths and trait
//! batches; ragged shapes are zero-padded into the nearest entry and the
//! padding sliced away — exact, since every statistic is a sum of
//! per-sample products). The suite has three kinds
//! ([`KernelKind`]): the trait-batched covariate-side `compress_xy`, the
//! shard-width-parameterized variant-side `compress_x` (one X-side pass
//! per shard covering all `T` traits, `O(shard_m·N_p)` resident block
//! memory), and the gathered-columns `select_gather` serving the SELECT
//! promote rounds.
//!
//! Two executors serve the suite behind one [`Engine`] API:
//!
//! - the **PJRT executor** (`--features xla-runtime`, the production hot
//!   path): `make artifacts` (Python, build-time only) lowers the suite
//!   to `artifacts/*.hlo.txt` + `manifest.json`; entries compile once on
//!   the CPU PJRT client and execute per sample block. HLO *text* is the
//!   interchange format (`HloModuleProto::from_text_file`, the
//!   id-renumbering parser — serialized protos from jax ≥ 0.5 are
//!   rejected by xla_extension 0.5.1). Matches the Rust kernels to fp
//!   tolerance. The engine is `!Send` (PJRT pointers) — each party
//!   thread owns its own [`Engine`], mirroring the one-process-per-party
//!   deployment.
//! - the **reference executor** (always available, both builds): the
//!   same padding/canonical-shape contract executed in pure Rust with
//!   per-element accumulation order identical to the streaming kernels —
//!   **bit-identical** to the Rust compute path, which is what the
//!   cross-backend conformance matrix pins down.
//!
//! [`Engine::open`] picks the executor per [`ArtifactExec`]
//! (`auto`/`pjrt`/`reference`); without the `xla-runtime` feature `pjrt`
//! fails with an explanatory error and `auto` resolves to the reference
//! executor, so artifact-mode sessions run in every build. Per-dispatch
//! telemetry (lowering-cache hits, per-kind pass counts, peak resident
//! padded-block bytes) flows through the shared [`KernelMeter`].

mod kernels;
mod manifest;

#[cfg(feature = "xla-runtime")]
mod engine;
#[cfg(not(feature = "xla-runtime"))]
#[path = "engine_stub.rs"]
mod engine;

pub use engine::Engine;
pub use kernels::{
    ArtifactExec, EngineOptions, EntryKey, KernelKind, KernelMeter, PassKind, ShapePolicy,
};
pub use manifest::Manifest;
