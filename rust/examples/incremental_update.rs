//! E7: a new center comes online after the initial analysis — the paper's
//! fn.1 claim that statistics update "at incremental cost ... independent
//! of the original number of samples".
//!
//! We combine an initial consortium, store only the O((K+T)·M) aggregate,
//! then time the update as a new center joins, for increasingly large
//! original cohorts. The update time stays flat while a from-scratch
//! recompute grows linearly.
//!
//! Run: `cargo run --release --example incremental_update`

use dash::coordinator::IncrementalAggregate;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::scan::compress_party;
use dash::util::human_secs;
use std::time::Instant;

fn spec(party_sizes: Vec<usize>, m: usize) -> CohortSpec {
    let p = party_sizes.len();
    CohortSpec {
        party_sizes,
        m_variants: m,
        n_traits: 1,
        n_causal: 5,
        effect_sd: 0.3,
        fst: 0.05,
        party_admixture: (0..p).map(|i| i as f64 / (p.max(2) - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn main() -> anyhow::Result<()> {
    let m = 2000;
    let n_new = 1000; // the joining center's size, fixed
    println!("new center: N_new = {n_new}, M = {m}");
    println!(
        "{:>10} {:>14} {:>16} {:>18}",
        "N_orig", "update_time", "recombine_time", "from_scratch_time"
    );

    for &n_orig in &[2_000usize, 8_000, 32_000, 128_000] {
        // initial consortium: 4 centers
        let cohort = generate_cohort(&spec(vec![n_orig / 4; 4], m), 900);
        let initial: Vec<_> = cohort
            .parties
            .iter()
            .map(|p| compress_party(&p.ys, &p.c, &p.x, 256, None))
            .collect();
        let mut inc = IncrementalAggregate::from_parties(&initial)?;
        let _ = inc.recombine()?;

        // the new center compresses locally (cost ∝ N_new, not N_orig)
        let joiner_cohort = generate_cohort(&spec(vec![n_new], m), 901);
        let jp = &joiner_cohort.parties[0];
        let t_update = Instant::now();
        let joiner_cp = compress_party(&jp.ys, &jp.c, &jp.x, 256, None);
        inc.add_parties(std::slice::from_ref(&joiner_cp))?;
        let update_time = t_update.elapsed().as_secs_f64();

        let t_rec = Instant::now();
        let updated = inc.recombine()?;
        let recombine_time = t_rec.elapsed().as_secs_f64();

        // from-scratch comparator: recompress everything
        let t_scratch = Instant::now();
        let mut all = initial.clone();
        // (recompression of original parties is the dominating cost)
        let re: Vec<_> = cohort
            .parties
            .iter()
            .map(|p| compress_party(&p.ys, &p.c, &p.x, 256, None))
            .collect();
        all.clear();
        all.extend(re);
        all.push(joiner_cp.clone());
        let scratch = IncrementalAggregate::from_parties(&all)?.recombine()?;
        let scratch_time = t_scratch.elapsed().as_secs_f64();

        // equivalence check
        let err = dash::linalg::rel_err(&updated.assoc[0].beta, &scratch.assoc[0].beta);
        assert!(err < 1e-10, "incremental != scratch: {err}");

        println!(
            "{:>10} {:>14} {:>16} {:>18}",
            n_orig,
            human_secs(update_time),
            human_secs(recombine_time),
            human_secs(scratch_time)
        );
    }
    println!("\nupdate_time and recombine_time are flat in N_orig;");
    println!("from_scratch_time grows linearly — the paper's fn.1 claim.");
    Ok(())
}
