//! E6: meta-analysis vs the pooled DASH scan under cross-party
//! heterogeneity ("analysts typically resort to meta-analyzing
//! within-party estimates, with loss of power ... as well as
//! between-group heterogeneity (c.f. Simpson's paradox)", §4).
//!
//! Sweeps the number of parties at fixed total N: as cohorts fragment,
//! inverse-variance meta-analysis loses power and picks up bias while
//! the pooled (DASH) scan is invariant — it computes the *exact* pooled
//! statistics from compressed pieces.
//!
//! Run: `cargo run --release --example meta_vs_pooled`

use dash::coordinator::run_multi_party_scan;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{meta_analyze, ScanConfig};

fn main() -> anyhow::Result<()> {
    let n_total = 3200;
    let m = 400;
    let n_causal = 30;
    let alpha = 1e-4;

    println!("total N = {n_total}, M = {m}, {n_causal} causal variants, alpha = {alpha:.0e}");
    println!(
        "{:>8} {:>13} {:>11} {:>12} {:>10} {:>13} {:>11}",
        "parties", "pooled_power", "meta_power", "pooled_fpr", "meta_fpr", "pooled_bias", "meta_bias"
    );

    let replicates = 5; // average over seeds — single-cohort power is noisy
    for &parties in &[2usize, 8, 16, 32, 64] {
        // pooled_power, meta_power, pooled_fpr, meta_fpr, pooled_bias, meta_bias
        let mut acc = [0.0f64; 6];
        for rep in 0..replicates {
            let spec = CohortSpec {
                party_sizes: vec![n_total / parties; parties],
                m_variants: m,
                n_traits: 1,
                n_causal,
                effect_sd: 0.25,
                fst: 0.1,
                party_admixture: (0..parties)
                    .map(|i| if parties == 1 { 0.5 } else { i as f64 / (parties - 1) as f64 })
                    .collect(),
                ancestry_effect: 0.8,
                batch_effect_sd: 0.4,
                n_pcs: 2,
                noise_sd: 1.0,
                binary_traits: false,
            };
            // same seeds across party counts → paired comparison
            let cohort = generate_cohort(&spec, 1000 + rep);

            let cfg = ScanConfig { backend: Backend::Plaintext, ..Default::default() };
            let pooled = run_multi_party_scan(&cohort, &cfg)?;
            let meta = meta_analyze(&cohort, 256)?;

            // power: fraction of causal variants detected at alpha
            let causal = &cohort.truth.causal_idx;
            let power = |ps: &[f64]| {
                causal.iter().filter(|&&j| ps[j].is_finite() && ps[j] < alpha).count() as f64
                    / causal.len() as f64
            };
            // bias: mean |β̂ − β̂_pooled| over causal variants — the pooled
            // estimate is the exact full-data statistic, so its own bias is
            // 0 by construction; meta deviates.
            let bias = |betas: &[f64]| {
                let mut s = 0.0;
                let mut c = 0;
                for &j in causal {
                    if betas[j].is_finite() && pooled.output.assoc[0].beta[j].is_finite() {
                        s += (betas[j] - pooled.output.assoc[0].beta[j]).abs();
                        c += 1;
                    }
                }
                s / c.max(1) as f64
            };
            // false-positive rate on null variants at a loose alpha —
            // meta's normal-approximation p-values are anticonservative
            // at small per-party df, which inflates both its "power" and
            // its type-I error
            let fpr_alpha = 0.01;
            let fpr = |ps: &[f64]| {
                let nulls: Vec<usize> =
                    (0..m).filter(|j| !causal.contains(j)).collect();
                nulls.iter().filter(|&&j| ps[j].is_finite() && ps[j] < fpr_alpha).count()
                    as f64
                    / nulls.len() as f64
            };
            acc[0] += power(&pooled.output.assoc[0].p);
            acc[1] += power(&meta.p);
            acc[2] += fpr(&pooled.output.assoc[0].p);
            acc[3] += fpr(&meta.p);
            acc[4] += bias(&pooled.output.assoc[0].beta);
            acc[5] += bias(&meta.beta);
        }
        let r = replicates as f64;
        println!(
            "{:>8} {:>13.3} {:>11.3} {:>12.4} {:>10.4} {:>13.2e} {:>11.2e}",
            parties,
            acc[0] / r,
            acc[1] / r,
            acc[2] / r,
            acc[3] / r,
            acc[4] / r,
            acc[5] / r
        );
    }
    println!("\npooled statistics are exact and calibrated at any fragmentation;");
    println!("meta-analysis drifts (bias grows with parties) and its normal-");
    println!("approximation p-values become anticonservative (fpr > 0.01) as");
    println!("per-party samples shrink — the motivation for the exact scan.");
    Ok(())
}
