//! End-to-end driver (EXPERIMENTS.md §E1/E4/E5): a realistic small
//! multi-center GWAS through the full three-layer stack.
//!
//! Four centers (total N = 8000), M = 20'000 variants, K = 7 covariates
//! (intercept, age, sex, 4 ancestry-PC scores). Compression runs through
//! the AOT artifacts (PJRT runtime) when `artifacts/` exists, else the
//! pure-Rust path; the combine stage uses pairwise-mask secure
//! aggregation. Reports throughput, per-phase timings, communication
//! totals, the secure-vs-plaintext overhead ratio, validation against
//! the pooled plaintext oracle, and the top hits.
//!
//! Run: `make artifacts && cargo run --release --example gwas_scan`
//! Smaller/faster: `cargo run --release --example gwas_scan -- --quick`

use dash::coordinator::run_multi_party_scan_t;
use dash::coordinator::Transport;
use dash::gwas::{generate_cohort, pool_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{
    combine_compressed, compress_party, flatten_for_sum, unflatten_sum, CombineOptions,
    RFactorMethod, ScanConfig,
};
use dash::util::{human_bytes, human_secs};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_total, m) = if quick { (2000, 4000) } else { (8000, 20_000) };
    let parties = 4;
    let seed = 20260710;

    let spec = CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 25,
        effect_sd: 0.12,
        fst: 0.08,
        party_admixture: vec![0.15, 0.4, 0.6, 0.85],
        ancestry_effect: 0.6,
        batch_effect_sd: 0.25,
        n_pcs: 4,
        noise_sd: 1.0,
        binary_traits: false,
    };
    eprintln!(
        "generating cohort: P={parties} N={n_total} M={m} K={} ...",
        spec.k_covariates()
    );
    let t0 = Instant::now();
    let cohort = generate_cohort(&spec, seed);
    eprintln!("cohort ready in {}", human_secs(t0.elapsed().as_secs_f64()));

    let use_artifacts = dash::runtime::Engine::load("artifacts").is_ok();
    eprintln!("artifact runtime: {}", if use_artifacts { "ENABLED" } else { "not found (rust path)" });

    // --- secure scan (the paper's protocol, sharded streaming) ---
    // 4096-variant shards: peak payload per round is O((K+T)·4096), parties
    // compress shard s+1 while the leader combines shard s, and the
    // result is bit-identical to the single-shot run below.
    let shard_m = 4096;
    let secure_cfg = ScanConfig {
        backend: Backend::Masked,
        use_artifacts,
        shard_m,
        ..Default::default()
    };
    let secure = run_multi_party_scan_t(&cohort, &secure_cfg, Transport::InProc, seed)?;

    // --- plaintext comparator (same distributed protocol, no crypto) ---
    let plain_cfg = ScanConfig {
        backend: Backend::Plaintext,
        use_artifacts,
        ..Default::default()
    };
    let plain = run_multi_party_scan_t(&cohort, &plain_cfg, Transport::InProc, seed)?;

    // --- pooled oracle for exactness (E5) ---
    eprintln!("computing pooled oracle ...");
    let pooled = pool_cohort(&cohort);
    let cp = compress_party(&pooled.ys, &pooled.c, &pooled.x, 256, None);
    let (layout, flat) = flatten_for_sum(&cp);
    let agg = unflatten_sum(layout, &flat)?;
    let oracle = combine_compressed(
        &agg,
        Some(std::slice::from_ref(&cp.r)),
        CombineOptions { r_method: RFactorMethod::Tsqr },
    )?;

    let mut max_rel_beta: f64 = 0.0;
    let mut max_abs_p: f64 = 0.0;
    for j in 0..m {
        let (a, b) = (secure.output.assoc[0].beta[j], oracle.assoc[0].beta[j]);
        if a.is_finite() && b.is_finite() {
            max_rel_beta = max_rel_beta.max((a - b).abs() / b.abs().max(1.0));
            max_abs_p =
                max_abs_p.max((secure.output.assoc[0].p[j] - oracle.assoc[0].p[j]).abs());
        }
    }

    let overhead = secure.metrics.total_s / plain.metrics.total_s;
    println!("\n=== gwas_scan (end-to-end driver) ===");
    println!("parties {parties}  N {n_total}  M {m}  K {}", cohort.k());
    println!("compute engine          {}", if use_artifacts { "AOT artifacts (PJRT)" } else { "pure Rust" });
    println!("--- secure (masked, {} shards of {shard_m}) ---", secure.metrics.shards);
    println!("  compress wall         {}", human_secs(secure.metrics.compress_wall_s));
    println!("  combine               {}", human_secs(secure.metrics.combine_s));
    println!("  total                 {}", human_secs(secure.metrics.total_s));
    println!("  variants/sec          {:.0}", m as f64 / secure.metrics.total_s);
    println!("  inter-party bytes     {}", human_bytes(secure.metrics.bytes_total));
    println!("  bytes/variant         {:.1}", secure.metrics.bytes_total as f64 / m as f64);
    println!("  peak round bytes      {}", human_bytes(secure.metrics.bytes_max_round));
    println!("--- plaintext comparator ---");
    println!("  total                 {}", human_secs(plain.metrics.total_s));
    println!("--- headline (E1) ---");
    println!("  secure/plaintext overhead ratio: {overhead:.3}x");
    println!("--- exactness vs pooled oracle (E5) ---");
    println!("  max rel err on beta   {max_rel_beta:.2e}");
    println!("  max abs err on p      {max_abs_p:.2e}");

    let alpha = 5e-8;
    let hits = secure.output.hits(alpha);
    let true_pos = hits.iter().filter(|h| cohort.truth.causal_idx.contains(h)).count();
    println!("--- hits (genome-wide alpha = {alpha:.0e}) ---");
    println!("  {} hits, {} truly causal (of {} causal variants)", hits.len(), true_pos, spec.n_causal);
    for &j in hits.iter().take(8) {
        println!(
            "  variant {:>6}  beta={:+.4}  se={:.4}  p={:.3e}{}",
            j,
            secure.output.assoc[0].beta[j],
            secure.output.assoc[0].se[j],
            secure.output.assoc[0].p[j],
            if cohort.truth.causal_idx.contains(&j) { "  [causal]" } else { "" }
        );
    }
    Ok(())
}
