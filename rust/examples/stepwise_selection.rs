//! Secure forward stepwise feature selection: after one scan, the
//! parties iteratively promote the strongest variants into the
//! covariate basis — each SELECT round costs one `O(H)` secure sum
//! (H = candidate shortlist), not a fresh `O((K+T)·M)` scan pass, and
//! the leader grows its cached QR basis by a rank-1 append.
//!
//! Run: `cargo run --release --example stepwise_selection`

use dash::coordinator::run_multi_party_scan;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{ScanConfig, SelectPolicy};
use dash::util::human_bytes;

fn main() -> anyhow::Result<()> {
    // Three centers, a cohort with several true causal variants.
    let mut spec = CohortSpec::default_small();
    spec.party_sizes = vec![400, 350, 300];
    spec.m_variants = 1000;
    spec.n_causal = 6;
    spec.effect_sd = 0.5;
    let cohort = generate_cohort(&spec, 77);
    println!(
        "cohort: {} parties, N={}, M={}, K={}  (true causal variants: {:?})",
        cohort.parties.len(),
        cohort.n_total(),
        cohort.m(),
        cohort.k(),
        cohort.truth.causal_idx
    );

    // One session: masked secure scan + 4 SELECT rounds over a
    // 32-variant shortlist, stopping early if nothing passes p < 1e-4.
    let cfg = ScanConfig {
        backend: Backend::Masked,
        shard_m: 256,
        select_k: 4,
        select_alpha: 1e-4,
        select_policy: SelectPolicy::Union,
        select_candidates: 32,
        ..Default::default()
    };
    let res = run_multi_party_scan(&cohort, &cfg)?;

    println!(
        "\nscan: {} variants in {:.1} ms, {} inter-party (peak scan round {})",
        cohort.m(),
        res.metrics.total_s * 1e3,
        human_bytes(res.metrics.bytes_total),
        human_bytes(res.metrics.bytes_max_round),
    );
    println!(
        "select: {} rounds, {} total, peak round {} — independent of M",
        res.metrics.select_rounds,
        human_bytes(res.metrics.bytes_select),
        human_bytes(res.metrics.bytes_max_select_round),
    );

    let sel = res.select.as_ref().expect("selection ran");
    println!("\nforward stepwise (shortlist H = {}):", sel.candidates.len());
    for round in &sel.rounds {
        for pick in round.picks.iter().flatten() {
            let causal = cohort.truth.causal_idx.contains(&pick.variant);
            println!(
                "  round {}: variant {:>4}  β̂ = {:+.4} ± {:.4}  p = {:.2e}{}",
                round.round,
                pick.variant,
                pick.beta,
                pick.se,
                pick.p,
                if causal { "  [truly causal]" } else { "" }
            );
        }
    }
    if sel.rounds.is_empty() {
        println!("  (no variant passed the entry threshold)");
    }

    // The model after selection: each promoted variant conditioned on
    // the ones before it — redundant hits in LD with an already-promoted
    // variant are *not* re-selected, which is the point of stepwise over
    // a plain top-k cut.
    let selected = sel.selected(0);
    let recovered = selected
        .iter()
        .filter(|v| cohort.truth.causal_idx.contains(v))
        .count();
    println!(
        "\nselected {:?} — {recovered}/{} truly causal",
        selected,
        selected.len()
    );
    Ok(())
}
