//! Quickstart: three hospitals jointly fit a linear regression and run a
//! small secure association scan — in ~40 lines of library calls.
//!
//! Run: `cargo run --release --example quickstart`

use dash::coordinator::run_multi_party_scan;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{combine_regression, compress_party, ScanConfig};

fn main() -> anyhow::Result<()> {
    // 1. Three centers with private cohorts (synthetic here).
    let spec = CohortSpec::default_small();
    let cohort = generate_cohort(&spec, 42);
    println!(
        "cohort: {} parties, N={}, M={}, K={}",
        cohort.parties.len(),
        cohort.n_total(),
        cohort.m(),
        cohort.k()
    );

    // 2. Multi-party linear regression (§2): compress within each party,
    //    combine across. Nothing sample-sized ever leaves a party.
    let compressed: Vec<_> = cohort
        .parties
        .iter()
        .map(|p| compress_party(&p.y, &p.c, &p.x, 64, None))
        .collect();
    let fit = combine_regression(&compressed)?;
    println!("\ncovariate fit (γ̂ ± se):");
    for (i, (g, s)) in fit.gamma.iter().zip(&fit.se).enumerate() {
        println!("  γ[{i}] = {g:+.4} ± {s:.4}   p = {:.2e}", fit.p[i]);
    }

    // 3. Secure multi-party association scan (§4): pairwise-mask secure
    //    aggregation; the leader sees only aggregate statistics.
    let cfg = ScanConfig { backend: Backend::Masked, ..Default::default() };
    let res = run_multi_party_scan(&cohort, &cfg)?;
    println!(
        "\nsecure scan: {} variants in {:.1} ms, {} bytes inter-party",
        cohort.m(),
        res.metrics.total_s * 1e3,
        res.metrics.bytes_total
    );
    let hits = res.output.hits(1e-6);
    println!("top hits (p < 1e-6):");
    for &j in hits.iter().take(5) {
        println!(
            "  variant {j:>4}  β̂ = {:+.4}  p = {:.2e}{}",
            res.output.assoc.beta[j],
            res.output.assoc.p[j],
            if cohort.truth.causal_idx.contains(&j) { "  [truly causal]" } else { "" }
        );
    }
    Ok(())
}
