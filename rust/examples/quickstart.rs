//! Quickstart: three hospitals jointly fit a linear regression and run a
//! small secure association scan — in ~40 lines of library calls. The
//! scan is trait-major: here three phenotypes ride the same session, and
//! the genotype-side cost is paid once for all of them.
//!
//! Run: `cargo run --release --example quickstart`

use dash::coordinator::run_multi_party_scan;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{combine_regression, compress_party, ScanConfig};

fn main() -> anyhow::Result<()> {
    // 1. Three centers with private cohorts (synthetic here), each
    //    carrying T = 3 phenotypes per sample.
    let mut spec = CohortSpec::default_small();
    spec.n_traits = 3;
    let cohort = generate_cohort(&spec, 42);
    println!(
        "cohort: {} parties, N={}, M={}, T={}, K={}",
        cohort.parties.len(),
        cohort.n_total(),
        cohort.m(),
        cohort.t(),
        cohort.k()
    );

    // 2. Multi-party linear regression (§2): compress within each party,
    //    combine across — one fit per trait. Nothing sample-sized ever
    //    leaves a party.
    let compressed: Vec<_> = cohort
        .parties
        .iter()
        .map(|p| compress_party(&p.ys, &p.c, &p.x, 64, None))
        .collect();
    let fits = combine_regression(&compressed)?;
    let fit = &fits[0];
    println!("\ncovariate fit, trait 0 (γ̂ ± se):");
    for (i, (g, s)) in fit.gamma.iter().zip(&fit.se).enumerate() {
        println!("  γ[{i}] = {g:+.4} ± {s:.4}   p = {:.2e}", fit.p[i]);
    }

    // 3. Secure multi-party association scan (§3/§4): pairwise-mask
    //    secure aggregation; the leader sees only aggregate statistics.
    //    All T traits are scanned in one session — the expensive
    //    genotype-side compression is shared.
    let cfg = ScanConfig { backend: Backend::Masked, ..Default::default() };
    let res = run_multi_party_scan(&cohort, &cfg)?;
    println!(
        "\nsecure scan: {} variants × {} traits in {:.1} ms, {} bytes inter-party",
        cohort.m(),
        cohort.t(),
        res.metrics.total_s * 1e3,
        res.metrics.bytes_total
    );
    for tt in 0..cohort.t() {
        let hits = res.output.hits_for(tt, 1e-6);
        println!("trait {tt}: {} hits (p < 1e-6)", hits.len());
        for &j in hits.iter().take(3) {
            println!(
                "  variant {j:>4}  β̂ = {:+.4}  p = {:.2e}{}",
                res.output.assoc[tt].beta[j],
                res.output.assoc[tt].p[j],
                if cohort.truth.causal_idx.contains(&j) { "  [truly causal]" } else { "" }
            );
        }
    }
    Ok(())
}
