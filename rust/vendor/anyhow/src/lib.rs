//! Minimal, API-compatible subset of the `anyhow` crate.
//!
//! Provides exactly the surface the `dash` crate uses — [`Result`],
//! [`Error`], and the `anyhow!` / `bail!` / `ensure!` macros — so the
//! workspace builds hermetically without a crates.io registry. The
//! design mirrors upstream `anyhow`: `Error` deliberately does **not**
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent.
//!
//! Differences from upstream (none observable to this workspace):
//! no backtraces, no downcasting, no `Context` extension trait.

use std::fmt;

/// `Result<T, anyhow::Error>` with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a message plus an optional chain of sources.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Chain a new high-level message in front of this error.
    pub fn context<M: fmt::Display>(self, m: M) -> Error {
        Error { msg: m.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` prints the whole chain
    /// separated by `": "` (matching upstream's alternate formatting).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()` panics and `fn main() -> Result<()>` exits print
        // through Debug — show the full chain there.
        write!(f, "{self:#}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as rendered messages.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let r: std::io::Result<()> = Err(io_err());
            r?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "disk on fire");
    }

    #[test]
    fn macros() {
        fn fails(x: usize) -> Result<usize> {
            ensure!(x > 1);
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(fails(5).unwrap(), 5);
        assert!(format!("{}", fails(1).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{}", fails(2).unwrap_err()), "x too small: 2");
        assert_eq!(format!("{}", fails(200).unwrap_err()), "x too big: 200");
        let e = anyhow!("plain {} message", 7);
        assert_eq!(format!("{e}"), "plain 7 message");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("a").context("b").context("c");
        let msgs: Vec<&str> = e.chain().collect();
        assert_eq!(msgs, vec!["c", "b", "a"]);
    }
}
