//! Party-dropout + checkpoint/resume battery: the chaos axis where a
//! party dies *permanently* mid-scan ([`FaultMode::Hangup`]) and the
//! session must never hang and never restart from zero. Three contracts:
//!
//! - **Degraded completion** (Shamir, share-sum leg): every survivor's
//!   sum already folds in all parties' contributions, so the leader
//!   reconstructs from a surviving quorum and the result is
//!   bit-identical to the clean run — with the death on record in
//!   `metrics.dropouts`.
//! - **Typed failure + checkpoint** (any backend, unrecoverable leg):
//!   the session fails with an error naming the dropped party, and the
//!   leader's per-shard snapshot survives on disk.
//! - **Resume**: re-running with `resume` skips the checkpointed shards
//!   (`metrics.shards_skipped`), recomputes only the rest, and the
//!   output is bit-identical to an uninterrupted session — absolute
//!   round numbering keeps every mask/share domain where an
//!   uninterrupted run would have used it.

mod common;

use common::{assert_run_matches, backends, cfg, spec_for};
use dash::coordinator::{
    checkpoint::checkpoint_path, run_multi_party_scan_t, run_session_batch, BatchOptions,
    Dropout, MultiPartyScanResult, SessionBatchResult, SessionSpec, Transport,
};
use dash::gwas::{generate_cohort, Cohort};
use dash::mpc::Backend;
use dash::net::chaos::{FaultDir, FaultMode, FaultSpec};
use dash::scan::ScanConfig;
use std::path::PathBuf;
use std::time::Duration;

/// Single-session batches: the one session's id (1-based).
const SID: u64 = 1;
const SEED: u64 = 7;

fn dropout_cohort() -> Cohort {
    // 3 parties × 24 samples, M = 24 → 3 shards at width 8
    generate_cohort(&spec_for(3, 24, 24, 1), 0xD0_0D)
}

/// Contribution frames the leader receives per party before round `r`
/// starts: plaintext/masked send one frame per round, Shamir two
/// (SHAMIR_OUT + SHAMIR_SUM). Round 0 is the base, round s+1 shard s.
fn frames_before_round(backend: Backend, round: u64) -> u64 {
    match backend {
        Backend::Shamir { .. } => 2 * round,
        _ => round,
    }
}

/// Hangup on the leader's receive side from party 0, starting at frame
/// `nth` of the victim session.
fn hangup(nth: u64) -> FaultSpec {
    FaultSpec {
        party: 0,
        dir: FaultDir::Recv,
        mode: FaultMode::Hangup,
        session: SID,
        nth,
    }
}

/// Run one single-session batch (the deployment shape whose transports
/// support fault injection) with a 2-second receive timeout bounding
/// every dead-party wait.
fn run_one(
    cohort: &Cohort,
    c: &ScanConfig,
    transport: Transport,
    fault: Option<FaultSpec>,
) -> SessionBatchResult {
    run_session_batch(
        cohort,
        &[SessionSpec { cfg: c.clone(), seed: SEED }],
        &BatchOptions {
            transport,
            max_concurrent: 1,
            recv_timeout: Some(Duration::from_secs(2)),
            fault,
        },
    )
    .unwrap()
}

fn baseline(cohort: &Cohort, backend: Backend) -> MultiPartyScanResult {
    run_multi_party_scan_t(cohort, &cfg(backend, 8), Transport::InProc, SEED).unwrap()
}

/// Fresh per-test checkpoint directory under the system temp dir.
fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "dash-dropout-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Shamir share-sum leg death: the victim's final SHAMIR_SUM frame (and
/// everything after) vanishes. The survivors' sums already carry every
/// party's contribution, so the session **completes** — bit-identical
/// to the clean run — and records exactly one dropout at the final
/// shard round.
#[test]
fn shamir_sum_leg_dropout_completes_degraded_and_bit_identical() {
    let cohort = dropout_cohort();
    let backend = Backend::Shamir { threshold: 2 };
    let serial = baseline(&cohort, backend);
    // rounds 0..=3 (base + 3 shards), 2 frames each = 8; frame 7 is the
    // last round's SHAMIR_SUM — the only recoverable leg
    let last_round = 3u64;
    let nth = frames_before_round(backend, last_round) + 1;
    let batch = run_one(&cohort, &cfg(backend, 8), Transport::InProc, Some(hangup(nth)));
    let run = batch.runs[0].as_ref().unwrap_or_else(|e| {
        panic!("sum-leg dropout must complete degraded, not fail: {e:#}")
    });
    assert_run_matches(run, &serial, "degraded shamir session");
    assert_eq!(
        run.metrics.dropouts,
        vec![Dropout { party: 0, round: last_round }],
        "exactly one recorded dropout at the last shard round"
    );
    assert_eq!(run.metrics.shards_skipped, 0, "no resume involved");
    // the dropped party was only partitioned leader-ward: it still
    // drains the result broadcast, so every party service completes
    assert_eq!(batch.failed, 0, "party services must all complete");
    assert_eq!(batch.residual_sessions, 0, "leaked sessions");
}

/// The core resume contract, for every backend: interrupt a
/// checkpointing session mid-scan (typed failure, snapshot on disk),
/// then resume — the resumed session skips the checkpointed shards and
/// its output is bit-identical to an uninterrupted run.
#[test]
fn interrupted_then_resumed_matches_uninterrupted_all_backends() {
    let cohort = dropout_cohort();
    for backend in backends() {
        let label = format!("{backend:?}");
        let serial = baseline(&cohort, backend);
        let dir = ckpt_dir(&label.replace([' ', '{', '}', ':'], ""));
        let mut c = cfg(backend, 8);
        c.checkpoint_dir = dir.to_str().unwrap().to_string();

        // Interrupt at shard 1 (round 2): shard 0 is already combined
        // and checkpointed, the death is unrecoverable on every
        // backend's round-entry leg → typed failure naming the party.
        let nth = frames_before_round(backend, 2);
        let batch = run_one(&cohort, &c, Transport::InProc, Some(hangup(nth)));
        let err = batch.runs[0]
            .as_ref()
            .err()
            .unwrap_or_else(|| panic!("{label}: interrupted session must fail"));
        let msg = format!("{err:#}");
        assert!(
            msg.contains("party 0") && msg.contains("dropped"),
            "{label}: failure must name the dropped party: {msg}"
        );
        let path = checkpoint_path(c.checkpoint_dir.as_str(), SID);
        assert!(path.exists(), "{label}: no checkpoint at {}", path.display());

        // Resume: no fault this time; the snapshot's shards are skipped
        // and the output is bit-identical to the uninterrupted run.
        c.resume = true;
        let batch = run_one(&cohort, &c, Transport::InProc, None);
        let run = batch.runs[0]
            .as_ref()
            .unwrap_or_else(|e| panic!("{label}: resume failed: {e:#}"));
        assert_run_matches(run, &serial, &format!("{label} resumed"));
        assert!(
            run.metrics.shards_skipped >= 1,
            "{label}: resume must skip checkpointed shards, skipped {}",
            run.metrics.shards_skipped
        );
        assert!(run.metrics.dropouts.is_empty(), "{label}: clean resume");
        assert!(
            !path.exists(),
            "{label}: checkpoint must be removed on clean completion"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A checkpoint from a *different* run configuration must refuse to
/// resume loudly — silently mixing statistics across seeds would be a
/// correctness hole, not a convenience.
#[test]
fn resume_with_mismatched_fingerprint_is_a_loud_error() {
    let cohort = dropout_cohort();
    let dir = ckpt_dir("fingerprint");
    let mut c = cfg(Backend::Masked, 8);
    c.checkpoint_dir = dir.to_str().unwrap().to_string();
    let batch = run_one(&cohort, &c, Transport::InProc, Some(hangup(2)));
    assert!(batch.runs[0].is_err(), "interrupted session must fail");
    assert!(checkpoint_path(c.checkpoint_dir.as_str(), SID).exists());

    // same session id, different seed → fingerprint mismatch
    c.resume = true;
    let batch = run_session_batch(
        &cohort,
        &[SessionSpec { cfg: c.clone(), seed: SEED + 1 }],
        &BatchOptions {
            transport: Transport::InProc,
            max_concurrent: 1,
            recv_timeout: Some(Duration::from_secs(2)),
            fault: None,
        },
    )
    .unwrap();
    let err = batch.runs[0].as_ref().err().expect("mismatched resume must fail");
    assert!(
        format!("{err:#}").contains("different run configuration"),
        "unexpected error: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI smoke: kill a party over real TCP, resume, and get the
/// uninterrupted answer bit-for-bit. The end-to-end shape of the
/// recovery story in one fast test
/// (`cargo test --test dropout_resume kill_and_resume`).
#[test]
fn kill_and_resume_smoke() {
    let cohort = dropout_cohort();
    let backend = Backend::Shamir { threshold: 2 };
    let serial = baseline(&cohort, backend);
    let dir = ckpt_dir("smoke");
    let mut c = cfg(backend, 8);
    c.checkpoint_dir = dir.to_str().unwrap().to_string();

    // kill the victim's share fan-out at shard 1 — unrecoverable leg
    let nth = frames_before_round(backend, 2);
    let batch = run_one(&cohort, &c, Transport::Tcp, Some(hangup(nth)));
    assert!(batch.runs[0].is_err(), "interrupted session must fail typed");
    assert!(checkpoint_path(c.checkpoint_dir.as_str(), SID).exists());

    c.resume = true;
    let batch = run_one(&cohort, &c, Transport::Tcp, None);
    let run = batch.runs[0].as_ref().unwrap_or_else(|e| panic!("resume failed: {e:#}"));
    assert_run_matches(run, &serial, "kill-and-resume over TCP");
    assert!(run.metrics.shards_skipped >= 1, "resume must skip shards");
    assert!(!checkpoint_path(c.checkpoint_dir.as_str(), SID).exists());
    let _ = std::fs::remove_dir_all(&dir);
}
