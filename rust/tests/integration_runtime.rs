//! Integration: the artifact kernel suite against the pure-Rust
//! reference path.
//!
//! The reference-executor tests always run (the executor is built into
//! every build) and assert the suite's *bit-level* contract. The
//! PJRT-executor tests require compiled artifacts (`make artifacts`) and
//! skip with a notice when absent, asserting the fp-tolerance contract.

use dash::gwas::{generate_cohort, CohortSpec};
use dash::linalg::{rel_err, solve_rt_b, Matrix};
use dash::runtime::{ArtifactExec, Engine, EngineOptions, KernelMeter, ShapePolicy};
use dash::scan::{compress_party, flatten_for_sum, unflatten_sum};
use dash::util::rng::Rng;

/// PJRT engine, `None` (skip) when this build / checkout has none.
fn pjrt_engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT runtime test (no compiled artifacts): {err:#}");
            None
        }
    }
}

fn ref_engine() -> Engine {
    Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap()
}

fn data(n: usize, k: usize, m: usize, t: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    let x = Matrix::randn(n, m, &mut rng);
    let ys = Matrix::randn(n, t, &mut rng);
    (ys, c, x)
}

#[test]
fn open_auto_resolves_to_reference_without_artifacts() {
    // no artifacts/ in a fresh checkout → Auto must still yield a
    // working engine (the reference executor)
    let e = Engine::open(&EngineOptions {
        dir: "definitely-not-an-artifact-dir".to_string(),
        exec: ArtifactExec::Auto,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(e.platform(), "reference");
    assert_eq!(e.entry_count(), 0, "entries lower lazily");
    // pjrt demanded explicitly → honest failure in artifact-less builds
    let forced = Engine::open(&EngineOptions {
        dir: "definitely-not-an-artifact-dir".to_string(),
        exec: ArtifactExec::Pjrt,
        ..Default::default()
    });
    assert!(forced.is_err());
}

#[test]
fn reference_compress_party_bit_identical_to_rust_path() {
    let e = ref_engine();
    for &(n, m, t) in &[(60usize, 40usize, 1usize), (130, 70, 2), (64, 64, 16)] {
        let (ys, c, x) = data(n, 5, m, t, 500 + n as u64);
        let fast = e.compress_party(&ys, &c, &x).unwrap();
        let slow = compress_party(&ys, &c, &x, 32, Some(2));
        assert_eq!(fast.n, slow.n);
        assert_eq!((fast.k(), fast.m(), fast.t()), (slow.k(), slow.m(), slow.t()));
        for (a, b) in fast.yty.iter().zip(&slow.yty) {
            assert_eq!(a.to_bits(), b.to_bits(), "yty n={n} m={m} t={t}");
        }
        assert_eq!(fast.cty.data, slow.cty.data, "cty n={n} m={m} t={t}");
        assert_eq!(fast.ctc.data, slow.ctc.data, "ctc n={n} m={m} t={t}");
        assert_eq!(fast.xty.data, slow.xty.data, "xty n={n} m={m} t={t}");
        assert_eq!(fast.xtx, slow.xtx, "xtx n={n} m={m} t={t}");
        assert_eq!(fast.ctx.data, slow.ctx.data, "ctx n={n} m={m} t={t}");
        // R factors identical too (same host-side Householder QR)
        assert_eq!(fast.r.data, slow.r.data, "r n={n} m={m} t={t}");
    }
}

#[test]
fn reference_per_shard_compress_matches_sliced_whole_block() {
    let e = ref_engine();
    let (ys, c, x) = data(80, 4, 53, 3, 501);
    let whole = e.compress_party(&ys, &c, &x).unwrap();
    for (j0, j1) in [(0usize, 20usize), (20, 40), (40, 53)] {
        let vb = e.compress_shard(&ys, &c, &x, j0, j1).unwrap();
        let sliced = whole.variant_block(j0, j1);
        assert_eq!(vb.xty.data, sliced.xty.data, "xty {j0}..{j1}");
        assert_eq!(vb.xtx, sliced.xtx, "xtx {j0}..{j1}");
        assert_eq!(vb.ctx.data, sliced.ctx.data, "ctx {j0}..{j1}");
    }
}

#[test]
fn reference_scan_stats_matches_rust_epilogue() {
    let e = ref_engine();
    let (ys, c, x) = data(300, 4, 33, 1, 502);
    let cp = compress_party(&ys, &c, &x, 64, Some(2));
    let (layout, flat) = flatten_for_sum(&cp);
    let agg = unflatten_sum(layout, &flat).unwrap();
    let r = dash::linalg::cholesky_upper(&agg.ctc).unwrap();
    let qty = solve_rt_b(&r, &agg.cty).data;
    let qtx = solve_rt_b(&r, &agg.ctx);
    let xty0 = agg.xty.col(0);
    let fast = e.scan_stats(agg.n, 4, agg.yty[0], &xty0, &agg.xtx, &qty, &qtx).unwrap();
    let slow = dash::stats::scan_stats_from_projected(&dash::stats::ScanStats {
        n: agg.n,
        k: 4,
        yty: agg.yty[0],
        xty: xty0.clone(),
        xtx: agg.xtx.clone(),
        qt_y: qty.clone(),
        qt_x: qtx.clone(),
    });
    for j in 0..33 {
        assert_eq!(fast.beta[j].to_bits(), slow.beta[j].to_bits(), "beta[{j}]");
        assert_eq!(fast.se[j].to_bits(), slow.se[j].to_bits(), "se[{j}]");
        assert_eq!(fast.p[j].to_bits(), slow.p[j].to_bits(), "p[{j}]");
    }
}

#[test]
fn genotype_dosage_compress_is_exact_on_reference() {
    // integer dosages are exactly representable in f64 → the suite and
    // the rust path agree bit-for-bit on xtx by the general contract;
    // this pins the historically-load-bearing dosage case specifically
    let mut rng = Rng::new(403);
    let (n, m, k) = (700usize, 90usize, 3usize);
    let mut c = Matrix::zeros(n, k);
    let mut x = Matrix::zeros(n, m);
    for i in 0..n {
        c[(i, 0)] = 1.0;
        c[(i, 1)] = rng.normal();
        c[(i, 2)] = rng.below(2) as f64;
        for j in 0..m {
            x[(i, j)] = rng.below(3) as f64;
        }
    }
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    let fast = ref_engine().compress_party(&ys, &c, &x).unwrap();
    let slow = compress_party(&ys, &c, &x, 32, Some(1));
    assert_eq!(fast.xtx, slow.xtx, "xtx must be exactly equal on dosages");
}

// ---- PJRT-executor tests (skip without compiled artifacts) ----

#[test]
fn pjrt_engine_loads_and_reports() {
    let Some(e) = pjrt_engine() else { return };
    assert_eq!(e.platform(), "cpu");
    let m = e.manifest.as_ref().expect("pjrt engine carries a manifest");
    assert!(m.n_block >= 64);
    assert!(m.k_pad >= 4);
}

#[test]
fn pjrt_compress_matches_rust_path() {
    let Some(e) = pjrt_engine() else { return };
    let mut rng = Rng::new(400);
    let nb = e.manifest.as_ref().unwrap().n_block;
    for &(n, m) in &[(60usize, 40usize), (nb, 64), (nb + 37, 83)] {
        let k = 5;
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        // two traits: exercises the trait-batched entries
        let ys = Matrix::randn(n, 2, &mut rng);
        let fast = e.compress_party(&ys, &c, &x).unwrap();
        let slow = compress_party(&ys, &c, &x, 64, Some(2));
        assert_eq!(fast.n, slow.n);
        assert_eq!(fast.t(), 2);
        assert!(rel_err(&fast.yty, &slow.yty) < 1e-12, "yty n={n} m={m}");
        assert!(rel_err(&fast.cty.data, &slow.cty.data) < 1e-12, "cty n={n} m={m}");
        assert!(rel_err(&fast.ctc.data, &slow.ctc.data) < 1e-12, "ctc n={n} m={m}");
        assert!(rel_err(&fast.xty.data, &slow.xty.data) < 1e-12, "xty n={n} m={m}");
        assert!(rel_err(&fast.xtx, &slow.xtx) < 1e-12, "xtx n={n} m={m}");
        assert!(rel_err(&fast.ctx.data, &slow.ctx.data) < 1e-12, "ctx n={n} m={m}");
        assert!(rel_err(&fast.r.data, &slow.r.data) < 1e-9, "r n={n} m={m}");
    }
}

#[test]
fn artifact_backed_multi_party_scan_runs_in_any_build() {
    // `Auto` resolves to PJRT when artifacts exist, reference otherwise;
    // either way the session must agree with the Rust-path session.
    let cohort = generate_cohort(&CohortSpec::default_small(), 402);
    let mut cfg = dash::scan::ScanConfig {
        backend: dash::mpc::Backend::Masked,
        block_m: 64,
        threads: Some(2),
        ..Default::default()
    };
    let rust_res = dash::coordinator::run_multi_party_scan(&cohort, &cfg).unwrap();
    cfg.use_artifacts = true;
    cfg.artifact_exec = ArtifactExec::Auto;
    let art_res = dash::coordinator::run_multi_party_scan(&cohort, &cfg).unwrap();
    // Same protocol, same fixed-point encoding; only the compress compute
    // engine differs → statistics agree to fixed-point noise (and
    // bit-exactly under the reference executor, pinned by the
    // conformance matrix).
    for j in 0..cohort.m() {
        let (a, b) = (art_res.output.assoc[0].beta[j], rust_res.output.assoc[0].beta[j]);
        if a.is_finite() && b.is_finite() {
            assert!((a - b).abs() < 1e-4 * b.abs().max(1.0), "beta[{j}]: {a} vs {b}");
        }
    }
    assert!(art_res.party_kernels.iter().all(|k| k.xside_passes() >= 1));
}
