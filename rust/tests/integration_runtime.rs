//! Integration: the AOT artifact runtime against the pure-Rust reference
//! path. Requires `artifacts/` (run `make artifacts` first); tests skip
//! with a notice when artifacts are absent so `cargo test` stays green in
//! a fresh checkout.

use dash::gwas::{generate_cohort, CohortSpec};
use dash::linalg::{rel_err, solve_rt_b, Matrix};
use dash::runtime::Engine;
use dash::scan::{compress_party, flatten_for_sum, unflatten_sum};
use dash::util::rng::Rng;

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime integration test (no artifacts): {err:#}");
            None
        }
    }
}

#[test]
fn engine_loads_and_reports() {
    let Some(e) = engine() else { return };
    assert_eq!(e.entry_count(), 3);
    assert_eq!(e.platform(), "cpu");
    assert!(e.manifest.n_block >= 64);
    assert!(e.manifest.k_pad >= 4);
}

#[test]
fn artifact_compress_matches_rust_path() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(400);
    // sizes straddling block boundaries: n < nb, n == nb, n > nb (tail),
    // m < mb, m > mb (tail)
    let nb = e.manifest.n_block;
    let mb = e.manifest.m_block;
    for &(n, m) in &[(60usize, 40usize), (nb, mb), (nb + 37, mb + 19), (3 * nb - 1, 2 * mb + 5)] {
        let k = 5;
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        // two traits: exercises the per-trait artifact loop
        let ys = Matrix::randn(n, 2, &mut rng);

        let fast = e.compress_party(&ys, &c, &x).unwrap();
        let slow = compress_party(&ys, &c, &x, 64, Some(2));

        assert_eq!(fast.n, slow.n);
        assert_eq!(fast.t(), 2);
        assert!(rel_err(&fast.yty, &slow.yty) < 1e-12, "yty n={n} m={m}");
        assert!(rel_err(&fast.cty.data, &slow.cty.data) < 1e-12, "cty n={n} m={m}");
        assert!(rel_err(&fast.ctc.data, &slow.ctc.data) < 1e-12, "ctc n={n} m={m}");
        assert!(rel_err(&fast.xty.data, &slow.xty.data) < 1e-12, "xty n={n} m={m}");
        assert!(rel_err(&fast.xtx, &slow.xtx) < 1e-12, "xtx n={n} m={m}");
        assert!(rel_err(&fast.ctx.data, &slow.ctx.data) < 1e-12, "ctx n={n} m={m}");
        // R factors agree (QR vs Cholesky of the same Gram)
        assert!(rel_err(&fast.r.data, &slow.r.data) < 1e-9, "r n={n} m={m}");
    }
}

#[test]
fn artifact_scan_stats_matches_rust_epilogue() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(401);
    let n = 300;
    let k = 4;
    for &m in &[10usize, e.manifest.m_block, e.manifest.m_block + 33] {
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|i| 0.3 * x[(i, 0)] + rng.normal()).collect();
        let cp = compress_party(&Matrix::from_col(y), &c, &x, 64, Some(2));
        let (layout, flat) = flatten_for_sum(&cp);
        let agg = unflatten_sum(layout, &flat).unwrap();
        let r = dash::linalg::cholesky_upper(&agg.ctc).unwrap();
        let qty = solve_rt_b(&r, &agg.cty).data;
        let qtx = solve_rt_b(&r, &agg.ctx);
        let xty0 = agg.xty.col(0);

        let fast = e
            .scan_stats(agg.n, k, agg.yty[0], &xty0, &agg.xtx, &qty, &qtx)
            .unwrap();
        let slow = dash::stats::scan_stats_from_projected(&dash::stats::ScanStats {
            n: agg.n,
            k,
            yty: agg.yty[0],
            xty: xty0.clone(),
            xtx: agg.xtx.clone(),
            qt_y: qty.clone(),
            qt_x: qtx.clone(),
        });
        for j in 0..m {
            assert!(
                (fast.beta[j] - slow.beta[j]).abs() < 1e-10 * slow.beta[j].abs().max(1.0),
                "beta[{j}] m={m}: {} vs {}",
                fast.beta[j],
                slow.beta[j]
            );
            assert!(
                (fast.se[j] - slow.se[j]).abs() < 1e-10 * slow.se[j].abs().max(1.0),
                "se[{j}] m={m}"
            );
            assert!(
                (fast.p[j] - slow.p[j]).abs() < 1e-8,
                "p[{j}] m={m}: {} vs {}",
                fast.p[j],
                slow.p[j]
            );
        }
    }
}

#[test]
fn artifact_backed_multi_party_scan_matches_rust_backed() {
    if engine().is_none() {
        return;
    }
    let cohort = generate_cohort(&CohortSpec::default_small(), 402);
    let mut cfg = dash::scan::ScanConfig {
        backend: dash::mpc::Backend::Masked,
        block_m: 64,
        threads: Some(2),
        ..Default::default()
    };
    let rust_res = dash::coordinator::run_multi_party_scan(&cohort, &cfg).unwrap();
    cfg.use_artifacts = true;
    let art_res = dash::coordinator::run_multi_party_scan(&cohort, &cfg).unwrap();
    // Same protocol, same fixed-point encoding; only the compress compute
    // engine differs → statistics agree to fixed-point noise.
    for j in 0..cohort.m() {
        let (a, b) = (art_res.output.assoc[0].beta[j], rust_res.output.assoc[0].beta[j]);
        if a.is_finite() && b.is_finite() {
            assert!((a - b).abs() < 1e-4 * b.abs().max(1.0), "beta[{j}]: {a} vs {b}");
        }
    }
}

#[test]
fn genotype_dosage_compress_is_exact() {
    // integer dosages are exactly representable in f64 → artifact and
    // rust paths agree bit-for-bit on xtx
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(403);
    let n = 700;
    let m = 90;
    let k = 3;
    let mut c = Matrix::zeros(n, k);
    let mut x = Matrix::zeros(n, m);
    for i in 0..n {
        c[(i, 0)] = 1.0;
        c[(i, 1)] = rng.normal();
        c[(i, 2)] = rng.below(2) as f64;
        for j in 0..m {
            x[(i, j)] = rng.below(3) as f64;
        }
    }
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    let fast = e.compress_party(&ys, &c, &x).unwrap();
    let slow = compress_party(&ys, &c, &x, 32, Some(1));
    assert_eq!(fast.xtx, slow.xtx, "xtx must be exactly equal on dosages");
}
