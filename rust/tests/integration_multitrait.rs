//! Integration: the trait-major secure pipeline (acceptance criteria of
//! the multi-trait tentpole) — a full secure multi-trait scan over every
//! backend and transport, `T = 1` bit-identical to the single-trait
//! reference computation, per-trait bit-identity between a T-trait
//! session and T independent single-trait sessions, and the
//! `O((K+T)·shard_m)` per-round payload bound.

mod common;

use common::{assert_bits_eq, backends, cfg, spec_for};
use dash::coordinator::{run_multi_party_scan_t, MultiPartyScanResult, Transport};
use dash::gwas::{generate_cohort, Cohort, PartyData};
use dash::linalg::Matrix;
use dash::mpc::field::Fe;
use dash::mpc::fixed::FixedCodec;
use dash::mpc::Backend;
use dash::scan::{
    combine_compressed, compress_party, shard_flat_len, unflatten_sum, CombineOptions,
    FlatLayout, RFactorMethod, ScanConfig,
};

fn run(
    cohort: &Cohort,
    backend: Backend,
    shard_m: usize,
    seed: u64,
) -> MultiPartyScanResult {
    common::run_inproc(cohort, backend, shard_m, seed)
}

/// Project a multi-trait cohort down to a single-trait cohort carrying
/// only trait `tt` (same covariates, genotypes, and causal truth).
fn single_trait_view(cohort: &Cohort, tt: usize) -> Cohort {
    let mut spec = cohort.spec.clone();
    spec.n_traits = 1;
    let parties = cohort
        .parties
        .iter()
        .map(|p| PartyData {
            ys: Matrix::from_col(p.ys.col(tt)),
            c: p.c.clone(),
            x: p.x.clone(),
        })
        .collect();
    Cohort { spec, parties, truth: cohort.truth.clone() }
}

/// Single-trait reference computation replicating the pre-trait-major
/// pipeline's numerics for one backend: per-party T = 1 compression,
/// backend-faithful aggregation of the flattened statistics (f64 sums in
/// party order for plaintext; fixed-point encode → exact ring/field sum
/// → decode for the secure backends), then the combine stage.
fn single_trait_reference(cohort: &Cohort, backend: Backend) -> dash::scan::ScanOutput {
    assert_eq!(cohort.t(), 1);
    let cps: Vec<_> = cohort
        .parties
        .iter()
        .map(|p| compress_party(&p.ys, &p.c, &p.x, 32, Some(2)))
        .collect();
    let (layout, _): (FlatLayout, _) = dash::scan::flatten_for_sum(&cps[0]);
    let flats: Vec<Vec<f64>> = cps.iter().map(|cp| dash::scan::flatten_for_sum(cp).1).collect();
    let codec = FixedCodec::new(ScanConfig::default().frac_bits);
    let summed: Vec<f64> = match backend {
        Backend::Plaintext => {
            let mut acc = vec![0.0f64; layout.len()];
            for f in &flats {
                for (a, b) in acc.iter_mut().zip(f) {
                    *a += b;
                }
            }
            acc
        }
        Backend::Masked => {
            // pairwise masks cancel exactly in the ring sum, so the
            // decoded aggregate equals the maskless ring sum bit-for-bit
            let mut acc = vec![0u64; layout.len()];
            for f in &flats {
                for (a, &v) in acc.iter_mut().zip(f) {
                    *a = a.wrapping_add(codec.encode(v).unwrap());
                }
            }
            acc.iter().map(|&r| codec.decode(r)).collect()
        }
        Backend::Shamir { .. } => {
            // Shamir reconstruction is exact field arithmetic: the
            // reconstructed sum equals the field sum of the encodings
            let mut acc = vec![Fe(0); layout.len()];
            for f in &flats {
                for (a, &v) in acc.iter_mut().zip(f) {
                    *a = a.add(Fe::from_i64(codec.encode(v).unwrap() as i64));
                }
            }
            acc.iter().map(|fe| fe.to_i64() as f64 / codec.scale()).collect()
        }
    };
    let agg = unflatten_sum(layout, &summed).unwrap();
    let (party_rs, r_method): (Option<Vec<Matrix>>, _) = match backend {
        // plaintext mode ships per-party R factors → Auto resolves TSQR
        Backend::Plaintext => {
            (Some(cps.iter().map(|cp| cp.r.clone()).collect()), RFactorMethod::Tsqr)
        }
        _ => (None, RFactorMethod::Cholesky),
    };
    combine_compressed(&agg, party_rs.as_deref(), CombineOptions { r_method }).unwrap()
}

/// Acceptance: a networked `T = 1` session reproduces the single-trait
/// reference bit-for-bit on every backend — the refactored pipeline *is*
/// the old single-trait pipeline at `T = 1`.
#[test]
fn networked_t1_bit_identical_to_single_trait_reference() {
    let cohort = generate_cohort(&spec_for(3, 80, 40, 1), 810);
    for backend in backends() {
        let session = run(&cohort, backend, 16, 51);
        let reference = single_trait_reference(&cohort, backend);
        assert_eq!(session.output.t(), 1, "{backend:?}");
        assert_bits_eq(&session.output.assoc[0].beta, &reference.assoc[0].beta, "beta");
        assert_bits_eq(&session.output.assoc[0].se, &reference.assoc[0].se, "se");
        assert_bits_eq(&session.output.assoc[0].p, &reference.assoc[0].p, "p");
        assert_bits_eq(
            &session.output.covariate_fit[0].gamma,
            &reference.covariate_fit[0].gamma,
            "gamma",
        );
    }
}

/// Acceptance: each trait of a secure multi-trait session is
/// bit-identical to an independent single-trait session over that trait,
/// for all three backends — amortization changes cost, never values.
#[test]
fn multi_trait_session_matches_t1_sessions_all_backends() {
    let t = 3;
    let cohort = generate_cohort(&spec_for(3, 70, 32, t), 811);
    for backend in backends() {
        let multi = run(&cohort, backend, 8, 52);
        assert_eq!(multi.output.t(), t, "{backend:?}");
        for tt in 0..t {
            let view = single_trait_view(&cohort, tt);
            let single = run(&view, backend, 8, 52);
            assert_bits_eq(
                &multi.output.assoc[tt].beta,
                &single.output.assoc[0].beta,
                &format!("{backend:?} trait {tt} beta"),
            );
            assert_bits_eq(
                &multi.output.assoc[tt].se,
                &single.output.assoc[0].se,
                &format!("{backend:?} trait {tt} se"),
            );
            assert_bits_eq(
                &multi.output.assoc[tt].p,
                &single.output.assoc[0].p,
                &format!("{backend:?} trait {tt} p"),
            );
        }
    }
}

/// Multi-trait sessions run over real TCP sockets with byte-identical
/// transcripts to the in-process transport.
#[test]
fn multi_trait_tcp_session_byte_identical() {
    let cohort = generate_cohort(&spec_for(3, 60, 24, 4), 812);
    for backend in backends() {
        let inproc =
            run_multi_party_scan_t(&cohort, &cfg(backend, 8), Transport::InProc, 53).unwrap();
        // TCP contends for sockets with the parallel test suite; allow one
        // retry before judging (byte accounting itself is deterministic).
        let mut last_err = String::new();
        let mut ok = false;
        for _attempt in 0..2 {
            let tcp =
                run_multi_party_scan_t(&cohort, &cfg(backend, 8), Transport::Tcp, 53).unwrap();
            if tcp.metrics.bytes_total == inproc.metrics.bytes_total {
                for tt in 0..4 {
                    assert_bits_eq(
                        &tcp.output.assoc[tt].beta,
                        &inproc.output.assoc[tt].beta,
                        &format!("{backend:?} trait {tt} beta"),
                    );
                }
                ok = true;
                break;
            }
            last_err = format!(
                "{backend:?}: bytes {} vs {}",
                tcp.metrics.bytes_total, inproc.metrics.bytes_total
            );
        }
        assert!(ok, "tcp/in-proc transcript mismatch after retry: {last_err}");
    }
}

/// Acceptance: peak per-round payload is O((K+T)·shard_m) — bounded by
/// the shard geometry plus the trait dimension, not by M.
#[test]
fn peak_round_bytes_bounded_by_k_plus_t_times_width() {
    let (parties, m, w, t) = (3usize, 128usize, 16usize, 8usize);
    let spec = spec_for(parties, 60, m, t);
    let k = spec.k_covariates();
    let cohort = generate_cohort(&spec, 813);
    let sharded = run(&cohort, Backend::Masked, w, 54);
    let single = run(&cohort, Backend::Masked, 0, 54);

    // Analytic bound: each party's shard-round frame carries the
    // w·(1+T+K) fixed-point words plus O(1) framing; the base round
    // (1 + T + KT + K²) is smaller for this geometry. 128 words of
    // slack per party absorbs all framing overhead.
    let flat_words = shard_flat_len(k, t, w) as u64;
    let bound = parties as u64 * 8 * (flat_words + 128);
    assert!(
        sharded.metrics.bytes_max_round <= bound,
        "peak round bytes {} exceed O((K+T)·shard_m) bound {bound}",
        sharded.metrics.bytes_max_round
    );
    // and the single-shot peak is ~M/w times larger, i.e. the bound is
    // really about the shard width, not M
    assert!(
        sharded.metrics.bytes_max_round * 4 <= single.metrics.bytes_max_round,
        "sharded peak {} not far below single-shot peak {}",
        sharded.metrics.bytes_max_round,
        single.metrics.bytes_max_round
    );

    // widening T at fixed w grows the round roughly ∝ (1+T+K)
    let spec16 = spec_for(parties, 60, m, 16);
    let cohort16 = generate_cohort(&spec16, 813);
    let sharded16 = run(&cohort16, Backend::Masked, w, 54);
    let expected_ratio = shard_flat_len(k, 16, w) as f64 / shard_flat_len(k, t, w) as f64;
    let ratio = sharded16.metrics.bytes_max_round as f64
        / sharded.metrics.bytes_max_round as f64;
    assert!(
        (ratio / expected_ratio - 1.0).abs() < 0.25,
        "round-bytes ratio {ratio} vs expected {expected_ratio}"
    );
}

/// Sharded multi-trait == single-shot multi-trait, bit-for-bit (the
/// two tentpoles compose).
#[test]
fn sharded_multi_trait_matches_single_shot() {
    let cohort = generate_cohort(&spec_for(3, 60, 48, 5), 814);
    let single = run(&cohort, Backend::Masked, 0, 55);
    let sharded = run(&cohort, Backend::Masked, 16, 55);
    assert_eq!(sharded.metrics.shards, 3);
    for tt in 0..5 {
        assert_bits_eq(
            &sharded.output.assoc[tt].beta,
            &single.output.assoc[tt].beta,
            &format!("trait {tt} beta"),
        );
        assert_bits_eq(
            &sharded.output.assoc[tt].p,
            &single.output.assoc[tt].p,
            &format!("trait {tt} p"),
        );
    }
}

/// The per-variant downlink and uplink totals scale with T the way the
/// paper's amortization argument says: uplink grows by ~ T·(M+K) words,
/// far below T times the single-trait session.
#[test]
fn trait_amortization_in_session_bytes() {
    let m = 200;
    let c1 = generate_cohort(&spec_for(3, 60, m, 1), 815);
    let c8 = generate_cohort(&spec_for(3, 60, m, 8), 815);
    let b1 = run(&c1, Backend::Masked, 0, 56).metrics.bytes_total;
    let b8 = run(&c8, Backend::Masked, 0, 56).metrics.bytes_total;
    // 8 traits cost far less than 8 independent sessions ...
    assert!(b8 < 4 * b1, "T=8 bytes {b8} vs 8 × T=1 sessions {}", 8 * b1);
    // ... but do cost more than one single-trait session
    assert!(b8 > b1, "T=8 bytes {b8} should exceed T=1 bytes {b1}");
}
