//! Fixed-point precision envelope (the contract documented in
//! `mpc/fixed.rs`): sweep joint trait/genotype magnitudes across
//! decades and pin the masked and Shamir backends to the plaintext scan
//! within the documented tolerance — plus codec-level error bounds per
//! decade. Nothing else in the suite stresses the encoding range; this
//! is what makes `frac_bits = 24` a contract instead of a hope.

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{Cohort, CohortSpec, PartyData, Truth};
use dash::linalg::Matrix;
use dash::mpc::fixed::FixedCodec;
use dash::mpc::Backend;
use dash::scan::ScanConfig;
use dash::util::rng::Rng;

/// Documented envelope: β̂/σ̂ agreement of the secure backends with
/// plaintext, relative with a small absolute floor (see mpc/fixed.rs).
const TOL_REL: f64 = 1e-3;
const TOL_ABS: f64 = 0.05;

/// Two-party cohort whose traits and genotypes are jointly scaled by
/// `s`: β̂, σ̂, t, p are scale-invariant, while every secure-summed
/// statistic scales by `s²` — exactly the fixed-point stressor.
fn scaled_cohort(scale: f64, m: usize, seed: u64) -> Cohort {
    let mut spec = CohortSpec::default_small();
    spec.party_sizes = vec![150, 130];
    spec.party_admixture = vec![0.5; 2];
    spec.m_variants = m;
    spec.n_traits = 1;
    spec.n_causal = 0;
    spec.n_pcs = 1; // K = 4
    let k = spec.k_covariates();
    let mut rng = Rng::new(seed);
    let mut parties = Vec::new();
    for &np in &spec.party_sizes {
        let mut c = Matrix::randn(np, k, &mut rng);
        for i in 0..np {
            c[(i, 0)] = 1.0;
        }
        let mut x = Matrix::randn(np, m, &mut rng);
        let mut ys = Matrix::randn(np, 1, &mut rng);
        for i in 0..np {
            ys[(i, 0)] += 0.4 * x[(i, 0)]; // planted effect, scale-free β
        }
        // joint scaling: y ← s·y, x ← s·x
        for v in ys.data.iter_mut() {
            *v *= scale;
        }
        for v in x.data.iter_mut() {
            *v *= scale;
        }
        parties.push(PartyData { ys, c, x });
    }
    Cohort {
        spec,
        parties,
        truth: Truth { causal_idx: vec![0], causal_beta: Matrix::zeros(1, 0), freqs: vec![] },
    }
}

fn close(a: f64, b: f64, what: &str, scale: f64, j: usize) {
    assert!(
        (a - b).abs() <= TOL_REL * b.abs().max(TOL_ABS),
        "{what}[{j}] at scale {scale}: secure {a} vs plaintext {b}"
    );
}

/// The envelope itself: five decades of joint magnitude, both secure
/// backends vs plaintext, β̂/σ̂ within (TOL_REL, TOL_ABS) and the
/// selected top hit identical.
#[test]
fn fixed_point_envelope_across_decades() {
    for (di, &scale) in [0.03f64, 0.3, 1.0, 10.0, 100.0].iter().enumerate() {
        let cohort = scaled_cohort(scale, 18, 950 + di as u64);
        let cfg = |backend| ScanConfig {
            backend,
            shard_m: 6,
            block_m: 8,
            threads: Some(2),
            ..Default::default()
        };
        let plain = run_multi_party_scan_t(
            &cohort,
            &cfg(Backend::Plaintext),
            Transport::InProc,
            70,
        )
        .unwrap();
        for backend in [Backend::Masked, Backend::Shamir { threshold: 2 }] {
            let res =
                run_multi_party_scan_t(&cohort, &cfg(backend), Transport::InProc, 70).unwrap();
            for j in 0..cohort.m() {
                let (a, b) = (res.output.assoc[0].beta[j], plain.output.assoc[0].beta[j]);
                if !b.is_finite() {
                    continue;
                }
                close(a, b, "beta", scale, j);
                close(res.output.assoc[0].se[j], plain.output.assoc[0].se[j], "se", scale, j);
            }
            // the planted hit survives the encoding at every decade
            assert_eq!(
                res.output.hits(1e-6).first(),
                plain.output.hits(1e-6).first(),
                "{backend:?} top hit at scale {scale}"
            );
        }
    }
}

/// Codec-level decade sweep: per-element round-trip error obeys the
/// 0.5/2^frac_bits bound at every magnitude the range check admits, and
/// the sum homomorphism holds exactly in the ring.
#[test]
fn codec_error_bound_across_decades() {
    let codec = FixedCodec::default();
    let mut rng = Rng::new(951);
    let mut mag = 1e-6f64;
    while mag <= 1e7 {
        if mag < codec.max_abs() {
            for _ in 0..500 {
                let v = rng.normal_ms(0.0, mag);
                if v.abs() > codec.max_abs() {
                    continue;
                }
                let err = (codec.decode(codec.encode(v).unwrap()) - v).abs();
                assert!(
                    err <= 0.5 / codec.scale() + 1e-15,
                    "mag {mag}: v={v} err={err:e}"
                );
            }
            // homomorphism: decode(Σ encode) == Σ rounded, exactly
            let vs: Vec<f64> = (0..6).map(|_| rng.normal_ms(0.0, mag)).collect();
            if vs.iter().all(|v| v.abs() <= codec.max_abs()) {
                let ring = vs
                    .iter()
                    .map(|&v| codec.encode(v).unwrap())
                    .fold(0u64, |a, b| a.wrapping_add(b));
                let want: f64 =
                    vs.iter().map(|&v| (v * codec.scale()).round() / codec.scale()).sum();
                assert!((codec.decode(ring) - want).abs() < 1e-9, "mag {mag}");
            }
        }
        mag *= 10.0;
    }
    // past the admitted range: clean rejection, never silent wrap
    assert!(codec.encode(codec.max_abs() * 1.01).is_err());
    assert!(codec.encode(-codec.max_abs() * 1.01).is_err());
}
