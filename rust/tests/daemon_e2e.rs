//! End-to-end daemon smoke: spawn the real `dash serve` binary on an
//! ephemeral port, drive it with the `dash jobs` client over real
//! localhost HTTP, and assert the fetched result is bit-identical
//! (`result_fp`) to a one-shot `dash scan` with the same parameters.
//!
//! The config handed to the daemon is not hand-written: the one-shot
//! scan's `--report` JSON embeds the exact resolved `RunConfig`, which
//! this test extracts and resubmits — so the parity check can never
//! drift from the CLI's cohort-override quirks.

use dash::util::json::Json;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_dash")
}

fn tempdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dash-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn serve_submit_poll_fetch_matches_one_shot_cli() {
    let dir = tempdir();
    let report = dir.join("report.json");

    // One-shot CLI run: sharded scan + 2 SELECT rounds.
    let out = Command::new(bin())
        .args([
            "scan", "--parties", "3", "--n", "48", "--m", "24", "--backend", "masked",
            "--shard-m", "8", "--select-k", "2", "--seed", "9", "--report",
            report.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "one-shot scan failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let rep = Json::parse(&std::fs::read_to_string(&report).unwrap()).unwrap();
    let want_fp = rep
        .get("result_fp")
        .and_then(Json::as_str)
        .expect("report carries result_fp")
        .to_string();
    // the stdout line agrees with the report (the e2e parse contract)
    let stdout = String::from_utf8_lossy(&out.stdout);
    let printed = stdout
        .lines()
        .find_map(|l| l.strip_prefix("result_fp"))
        .expect("scan printed no result_fp line")
        .trim()
        .to_string();
    assert_eq!(printed, want_fp);

    // The daemon gets the *resolved* config from the report.
    let cfg_path = dir.join("job.json");
    std::fs::write(&cfg_path, rep.get("config").expect("report embeds config").to_string())
        .unwrap();

    // Spawn the daemon on an ephemeral port; it announces the bound
    // address on its first stdout line.
    let mut child = Command::new(bin())
        .args(["serve", "--listen", "127.0.0.1:0", "--checkpoint-dir",
            dir.join("ckpt").to_str().unwrap()])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout_pipe = child.stdout.take().unwrap();
    let _guard = KillOnDrop(child);
    let first = BufReader::new(stdout_pipe)
        .lines()
        .next()
        .expect("daemon exited before announcing its address")
        .unwrap();
    let addr = first
        .strip_prefix("dash daemon listening on ")
        .unwrap_or_else(|| panic!("unexpected announce line: {first}"))
        .trim()
        .to_string();

    // Health must answer promptly once the address is printed.
    let t0 = Instant::now();
    loop {
        let h = Command::new(bin()).args(["jobs", "health", "--addr", &addr]).output().unwrap();
        if h.status.success() {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "daemon never became healthy: {}",
            String::from_utf8_lossy(&h.stderr)
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // submit → poll → fetch through the client; --wait prints the
    // result summary with its parity fingerprint.
    let sub = Command::new(bin())
        .args([
            "jobs", "submit", "--addr", &addr, "--config", cfg_path.to_str().unwrap(),
            "--tenant", "e2e", "--wait",
        ])
        .output()
        .unwrap();
    assert!(
        sub.status.success(),
        "jobs submit failed: {}\n{}",
        String::from_utf8_lossy(&sub.stdout),
        String::from_utf8_lossy(&sub.stderr)
    );
    let sub_out = String::from_utf8_lossy(&sub.stdout);
    let got_fp = sub_out
        .lines()
        .find_map(|l| l.strip_prefix("result_fp "))
        .expect("jobs submit --wait printed no result_fp")
        .trim()
        .to_string();
    assert_eq!(got_fp, want_fp, "daemon vs one-shot CLI parity");

    // the dedicated result route agrees
    let res = Command::new(bin())
        .args(["jobs", "result", "--addr", &addr, "--id", "1"])
        .output()
        .unwrap();
    assert!(res.status.success(), "{}", String::from_utf8_lossy(&res.stderr));
    let res_out = String::from_utf8_lossy(&res.stdout);
    assert!(
        res_out.contains(&format!("result_fp {want_fp}")),
        "jobs result output: {res_out}"
    );

    // no checkpoint residue for the completed job
    assert!(
        !dir.join("ckpt/job-1").exists(),
        "daemon left a checkpoint directory for a finished job"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
