//! Integration: experiment-level checks — E6 (meta vs pooled under
//! heterogeneity), E7 (incremental update equivalence), E9 (TSQR vs
//! Gram+Cholesky conditioning ablation).

use dash::coordinator::IncrementalAggregate;
use dash::gwas::{generate_cohort, CohortSpec};
use dash::linalg::{cholesky_upper, householder_qr, rel_err, tsqr_stack_r, Matrix};
use dash::scan::{compress_party, meta_analyze};
use dash::util::rng::Rng;

/// E6: under cross-party heterogeneity (confounded batch effects +
/// divergent ancestry), pooled covariate-adjusted DASH keeps power that
/// per-party meta-analysis loses.
#[test]
fn e6_meta_loses_power_under_heterogeneity() {
    let spec = CohortSpec {
        // many small parties: the regime where meta is weakest
        party_sizes: vec![35; 10],
        m_variants: 80,
        n_traits: 1,
        n_causal: 8,
        effect_sd: 0.35,
        fst: 0.1,
        party_admixture: (0..10).map(|i| i as f64 / 9.0).collect(),
        ancestry_effect: 0.8,
        batch_effect_sd: 0.5,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    };
    let cohort = generate_cohort(&spec, 700);

    // pooled scan (plaintext path suffices for the statistical claim)
    let cfg = dash::scan::ScanConfig {
        backend: dash::mpc::Backend::Plaintext,
        block_m: 40,
        threads: Some(2),
        ..Default::default()
    };
    let pooled = dash::coordinator::run_multi_party_scan(&cohort, &cfg).unwrap();
    let meta = meta_analyze(&cohort, 40).unwrap();

    let alpha = 1e-3;
    let causal: Vec<usize> = cohort.truth.causal_idx.clone();
    let power = |ps: &[f64]| -> f64 {
        causal.iter().filter(|&&j| ps[j].is_finite() && ps[j] < alpha).count() as f64
            / causal.len() as f64
    };
    let pooled_power = power(&pooled.output.assoc[0].p);
    let meta_power = power(&meta.p);
    assert!(
        pooled_power >= meta_power,
        "pooled power {pooled_power} < meta power {meta_power}"
    );
    // and pooled must actually find something in this design
    assert!(pooled_power > 0.3, "pooled power only {pooled_power}");
}

/// E7: incremental update equals full recompute, and the retained state
/// is O(K·M) regardless of history.
#[test]
fn e7_incremental_matches_full_recompute() {
    let mut rng = Rng::new(701);
    let k = 4;
    let m = 30;
    let make = |n: usize, rng: &mut Rng| {
        let mut c = Matrix::randn(n, k, rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, rng);
        let y: Vec<f64> = (0..n).map(|i| 0.25 * x[(i, 1)] + rng.normal()).collect();
        compress_party(&Matrix::from_col(y), &c, &x, m, Some(1))
    };
    let initial: Vec<_> = (0..3).map(|_| make(90, &mut rng)).collect();
    let joiners: Vec<_> = (0..2).map(|_| make(150, &mut rng)).collect();

    let mut inc = IncrementalAggregate::from_parties(&initial).unwrap();
    let before = inc.recombine().unwrap();
    inc.add_parties(&joiners).unwrap();
    let after = inc.recombine().unwrap();

    let mut all = initial.clone();
    all.extend(joiners.clone());
    let full = IncrementalAggregate::from_parties(&all).unwrap().recombine().unwrap();

    assert!(rel_err(&after.assoc[0].beta, &full.assoc[0].beta) < 1e-12);
    assert!(rel_err(&after.assoc[0].se, &full.assoc[0].se) < 1e-12);
    // more data → tighter intervals at the causal variant
    assert!(after.assoc[0].se[1] < before.assoc[0].se[1]);
}

/// E9: TSQR and Gram+Cholesky agree on well-conditioned inputs and
/// diverge as conditioning degrades — with TSQR tracking the true R
/// better (that is the reason the plaintext path prefers it).
#[test]
fn e9_tsqr_vs_cholesky_conditioning() {
    let mut rng = Rng::new(702);
    let k = 6;
    let n_per = 200;
    let parties = 3;

    let mut last_gap = 0.0;
    for &cond_scale in &[1.0, 1e-4, 1e-7] {
        // build per-party covariates with one nearly-dependent column
        let mut cs = Vec::new();
        for _ in 0..parties {
            let mut c = Matrix::randn(n_per, k, &mut rng);
            for i in 0..n_per {
                c[(i, 0)] = 1.0;
                // column k-1 = column 1 + tiny noise → condition blows up
                c[(i, k - 1)] = c[(i, 1)] + cond_scale * c[(i, k - 1)];
            }
            cs.push(c);
        }
        let refs: Vec<&Matrix> = cs.iter().collect();
        let full = Matrix::vstack(&refs);
        let r_true = householder_qr(&full).r;

        let rs: Vec<Matrix> = cs.iter().map(|c| householder_qr(c).r).collect();
        let r_tsqr = tsqr_stack_r(&rs);

        let mut gram = Matrix::zeros(k, k);
        for c in &cs {
            gram = gram.add(&c.gram());
        }
        let r_chol = cholesky_upper(&gram).unwrap();

        let err_tsqr = rel_err(&r_tsqr.data, &r_true.data);
        let err_chol = rel_err(&r_chol.data, &r_true.data);
        // TSQR should never be (much) worse
        assert!(
            err_tsqr <= 10.0 * err_chol.max(1e-14),
            "cond={cond_scale}: tsqr {err_tsqr} vs chol {err_chol}"
        );
        last_gap = err_chol / err_tsqr.max(1e-16);
    }
    // at the worst conditioning, Cholesky should be measurably worse
    assert!(last_gap > 1.0, "expected Cholesky to degrade, gap={last_gap}");
}

/// E3 sanity at test scale: combine work does not grow with N.
#[test]
fn e3_combine_inputs_independent_of_n() {
    let mut rng = Rng::new(703);
    let k = 5;
    let m = 40;
    let sizes = [100usize, 1000];
    let mut flat_lens = Vec::new();
    for &n in &sizes {
        let mut c = Matrix::randn(n, k, &mut rng);
        for i in 0..n {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(n, m, &mut rng);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let cp = compress_party(&Matrix::from_col(y), &c, &x, m, Some(1));
        let (layout, flat) = dash::scan::flatten_for_sum(&cp);
        assert_eq!(flat.len(), layout.len());
        flat_lens.push(flat.len());
    }
    assert_eq!(flat_lens[0], flat_lens[1], "combine input size must not depend on N");
}
