//! Integration: the SELECT phase (forward stepwise over cached
//! compressed statistics) — oracle equality across all three MPC
//! backends, per-trait-policy decomposition, the E9 communication bound
//! (per-round SELECT traffic independent of M), and the
//! duplicate-frame → protocol-ErrorMsg regression.

mod common;

use common::backends;
use dash::coordinator::messages::{
    error_frame, Compress, PlainBase, PlainShard, Setup, TAG_ERROR,
};
use dash::coordinator::{run_multi_party_scan_t, MultiPartyScanResult, Transport};
use dash::gwas::{pool_cohort, Cohort, CohortSpec, PartyData, Truth};
use dash::linalg::{householder_qr, Matrix};
use dash::mpc::Backend;
use dash::net::{duplex_pair, ByteMeter, WireMessage};
use dash::scan::{compress_party, ScanConfig, SelectPolicy, ShardPlan};
use dash::stats::scan_stats_from_projected_parts;
use dash::util::rng::Rng;

/// Hand-built cohort with *planted, well-separated* effects so stepwise
/// selection is deterministic across fixed-point backends. `effects` is
/// `(trait, variant, beta)`.
fn synth_cohort(
    party_sizes: &[usize],
    m: usize,
    t: usize,
    seed: u64,
    effects: &[(usize, usize, f64)],
) -> Cohort {
    let mut spec = CohortSpec::default_small();
    spec.party_sizes = party_sizes.to_vec();
    spec.party_admixture = vec![0.5; party_sizes.len()];
    spec.m_variants = m;
    spec.n_traits = t;
    spec.n_causal = 0;
    spec.n_pcs = 1; // K = 4
    let k = spec.k_covariates();
    let mut rng = Rng::new(seed);
    let mut parties = Vec::with_capacity(party_sizes.len());
    for &np in party_sizes {
        let mut c = Matrix::randn(np, k, &mut rng);
        for i in 0..np {
            c[(i, 0)] = 1.0;
        }
        let x = Matrix::randn(np, m, &mut rng);
        let mut ys = Matrix::randn(np, t, &mut rng);
        for &(tt, j, beta) in effects {
            for i in 0..np {
                ys[(i, tt)] += beta * x[(i, j)];
            }
        }
        parties.push(PartyData { ys, c, x });
    }
    Cohort {
        spec,
        parties,
        truth: Truth {
            causal_idx: effects.iter().map(|e| e.1).collect(),
            causal_beta: Matrix::zeros(t, 0),
            freqs: vec![],
        },
    }
}

fn cfg(backend: Backend, m: usize, select_k: usize, alpha: f64) -> ScanConfig {
    ScanConfig {
        select_k,
        select_alpha: alpha,
        select_candidates: m, // unrestricted: shortlist = all finite-p variants
        ..common::cfg(backend, 16)
    }
}

fn run(cohort: &Cohort, cfg: &ScanConfig, seed: u64) -> MultiPartyScanResult {
    run_multi_party_scan_t(cohort, cfg, Transport::InProc, seed).unwrap()
}

/// Brute-force forward stepwise on the pooled raw data, same scoring
/// rule as the protocol: per round, min entry p-value over (traits ×
/// candidates), ties to the earlier trait then lower variant index;
/// stop at `p > alpha`. Returns `(variant, trait, beta, se, p)`.
fn oracle_stepwise(
    pooled: &PartyData,
    traits: &[usize],
    cand: &[usize],
    k_max: usize,
    alpha: f64,
) -> Vec<(usize, usize, f64, f64, f64)> {
    let n = pooled.ys.rows;
    let xs = pooled.x.gather_cols(cand);
    let xtx: Vec<f64> = (0..xs.cols).map(|j| xs.col(j).iter().map(|v| v * v).sum()).collect();
    let mut basis = pooled.c.clone();
    let mut chosen: Vec<usize> = Vec::new();
    let mut out = Vec::new();
    for _ in 0..k_max {
        let f = householder_qr(&basis);
        let qt_x = f.q.t_matmul(&xs);
        let mut best: Option<(usize, usize, f64, f64, f64)> = None;
        for &tt in traits {
            let y = pooled.ys.col(tt);
            let yty: f64 = y.iter().map(|v| v * v).sum();
            let assoc = scan_stats_from_projected_parts(
                n,
                basis.cols,
                yty,
                &xs.t_matvec(&y),
                &xtx,
                &f.q.t_matvec(&y),
                &qt_x,
            );
            for slot in 0..xs.cols {
                if chosen.contains(&slot) {
                    continue;
                }
                let p = assoc.p[slot];
                if !p.is_finite() || p > alpha {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => p < b.4,
                };
                if better {
                    best = Some((cand[slot], tt, assoc.beta[slot], assoc.se[slot], p));
                }
            }
        }
        let Some(b) = best else { break };
        chosen.push(cand.iter().position(|&c| c == b.0).unwrap());
        basis = Matrix::vstack(&[
            &basis.transpose(),
            &Matrix::from_col(pooled.x.col(b.0)).transpose(),
        ])
        .transpose();
        out.push(b);
    }
    out
}

/// Acceptance: the stepwise selection sequence is identical across all
/// three backends and matches the plaintext pooled-data oracle for
/// k ∈ {1, 2, 3}; plaintext entry statistics match the oracle tightly,
/// secure backends within fixed-point tolerance.
#[test]
fn selection_equals_oracle_all_backends() {
    let cohort = synth_cohort(
        &[110, 90, 100],
        24,
        1,
        900,
        &[(0, 3, 0.7), (0, 11, 0.45), (0, 17, 0.3)],
    );
    let pooled = pool_cohort(&cohort);
    for k in 1..=3usize {
        let plain = run(&cohort, &cfg(Backend::Plaintext, 24, k, 1e-3), 60);
        let sel = plain.select.as_ref().expect("plaintext select output");
        let want = oracle_stepwise(&pooled, &[0], &sel.candidates, k, 1e-3);
        assert_eq!(want.len(), k, "oracle should fill all {k} rounds");
        assert_eq!(
            sel.selected(0),
            want.iter().map(|w| w.0).collect::<Vec<_>>(),
            "plaintext selection vs oracle, k={k}"
        );
        for (round, w) in sel.rounds.iter().zip(&want) {
            let p = round.picks[0].as_ref().unwrap();
            assert!((p.beta - w.2).abs() < 1e-6 * w.2.abs().max(1.0), "beta k={k}");
            assert!((p.se - w.3).abs() < 1e-6 * w.3.abs().max(1.0), "se k={k}");
        }

        for backend in backends().into_iter().filter(|b| *b != Backend::Plaintext) {
            let res = run(&cohort, &cfg(backend, 24, k, 1e-3), 60);
            let s = res.select.as_ref().expect("secure select output");
            assert_eq!(
                s.selected(0),
                sel.selected(0),
                "{backend:?} selection sequence, k={k}"
            );
            for (a, b) in s.rounds.iter().zip(&sel.rounds) {
                let (pa, pb) = (a.picks[0].as_ref().unwrap(), b.picks[0].as_ref().unwrap());
                assert!(
                    (pa.beta - pb.beta).abs() < 1e-3 * pb.beta.abs().max(1.0),
                    "{backend:?} entry beta"
                );
            }
        }
    }
}

/// Per-trait policy: a T = 2 session's lane `t` reproduces an
/// independent T = 1 session of that trait, bit-for-bit on the released
/// entry statistics.
#[test]
fn per_trait_policy_matches_independent_runs() {
    let effects = [(0usize, 2usize, 0.6f64), (0, 7, 0.35), (1, 5, 0.55), (1, 9, 0.4)];
    let joint = synth_cohort(&[120, 100], 16, 2, 901, &effects);
    for backend in [Backend::Plaintext, Backend::Masked] {
        let mut c = cfg(backend, 16, 2, 1e-2);
        c.select_policy = SelectPolicy::PerTrait;
        let res = run(&joint, &c, 61);
        let sel = res.select.as_ref().expect("select output");
        assert_eq!(sel.lanes(), 2);

        for tt in 0..2usize {
            // same parties, single trait column
            let mut solo = joint.clone();
            solo.spec.n_traits = 1;
            for p in &mut solo.parties {
                p.ys = Matrix::from_col(p.ys.col(tt));
            }
            let solo_res = run(&solo, &cfg(backend, 16, 2, 1e-2), 61);
            let solo_sel = solo_res.select.as_ref().expect("solo select output");
            assert_eq!(sel.selected(tt), solo_sel.selected(0), "{backend:?} trait {tt}");
            for (a, b) in sel.rounds.iter().zip(&solo_sel.rounds) {
                match (&a.picks[tt], &b.picks[0]) {
                    (Some(pa), Some(pb)) => {
                        assert_eq!(pa.variant, pb.variant);
                        assert_eq!(pa.beta.to_bits(), pb.beta.to_bits(), "{backend:?} beta");
                        assert_eq!(pa.p.to_bits(), pb.p.to_bits(), "{backend:?} p");
                    }
                    (None, None) => {}
                    other => panic!("{backend:?} lane/solo divergence: {other:?}"),
                }
            }
        }
    }
}

/// E9 acceptance: per-SELECT-round wire bytes depend on (K, T, H,
/// lanes), **not** on M — byte-identical rounds at 4× the variant count
/// — and a SELECT round is ≫ cheaper than a scan contribution round.
#[test]
fn select_round_bytes_independent_of_m() {
    let mk = |m: usize| {
        let cohort =
            synth_cohort(&[90, 80, 70], m, 1, 902, &[(0, 1, 0.6), (0, 5, 0.4)]);
        let mut c = cfg(Backend::Masked, m, 2, 0.9);
        c.select_candidates = 8; // bounded shortlist H = 8
        c.shard_m = 64;
        run(&cohort, &c, 62)
    };
    let small = mk(120);
    let large = mk(480);
    assert_eq!(small.metrics.select_rounds, 2);
    assert_eq!(large.metrics.select_rounds, 2);
    assert_eq!(small.select.as_ref().unwrap().candidates.len(), 8);
    assert_eq!(large.select.as_ref().unwrap().candidates.len(), 8);
    // identical per-round SELECT bytes at 4× M
    assert!(small.metrics.bytes_max_select_round > 0);
    assert_eq!(
        small.metrics.bytes_max_select_round, large.metrics.bytes_max_select_round,
        "per-round SELECT bytes must not scale with M"
    );
    // and each SELECT round is far below a scan shard round
    assert!(
        small.metrics.bytes_max_select_round * 4 < small.metrics.bytes_max_round,
        "select round {} vs scan round {}",
        small.metrics.bytes_max_select_round,
        small.metrics.bytes_max_round
    );
    // the whole SELECT phase is far below the scan's total traffic
    assert!(large.metrics.bytes_select * 4 < large.metrics.bytes_total);
}

/// Selection runs unchanged over TCP with identical bytes and picks.
#[test]
fn select_tcp_matches_inproc() {
    let cohort = synth_cohort(&[80, 70], 20, 1, 903, &[(0, 4, 0.6), (0, 13, 0.4)]);
    let c = cfg(Backend::Masked, 20, 2, 1e-2);
    let a = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 63).unwrap();
    let mut last_err = String::new();
    for _attempt in 0..2 {
        let b = run_multi_party_scan_t(&cohort, &c, Transport::Tcp, 63).unwrap();
        if b.metrics.bytes_total == a.metrics.bytes_total {
            assert_eq!(
                a.select.as_ref().unwrap().selected(0),
                b.select.as_ref().unwrap().selected(0)
            );
            assert_eq!(
                a.metrics.bytes_max_select_round,
                b.metrics.bytes_max_select_round
            );
            return;
        }
        last_err =
            format!("bytes {} vs {}", b.metrics.bytes_total, a.metrics.bytes_total);
    }
    panic!("tcp/in-proc select mismatch after retry: {last_err}");
}

/// A threshold no variant passes → zero rounds, empty-but-present
/// select output, and the session still completes cleanly.
#[test]
fn select_stop_rule_yields_zero_rounds() {
    let cohort = synth_cohort(&[100, 90], 12, 1, 904, &[(0, 2, 0.5)]);
    let res = run(&cohort, &cfg(Backend::Masked, 12, 3, 1e-300), 64);
    assert_eq!(res.metrics.select_rounds, 0);
    let sel = res.select.as_ref().expect("shortlist existed");
    assert!(sel.rounds.is_empty());
    assert!(res.output.min_p_value().is_some());
}

/// Regression (duplicate-frame handling): a party re-delivering a shard
/// frame must make the leader fail the session with a protocol
/// `ErrorMsg` broadcast — not a panic, not a silent double-count. We
/// play the (single) party by hand over an in-proc link.
#[test]
fn duplicate_shard_frame_yields_protocol_error() {
    let cohort = synth_cohort(&[80], 8, 1, 905, &[(0, 1, 0.5)]);
    let data = cohort.parties[0].clone();
    let c = ScanConfig {
        backend: Backend::Plaintext,
        shard_m: 4, // 2 shards
        block_m: 8,
        threads: Some(1),
        ..Default::default()
    };
    let meter = ByteMeter::new();
    let (leader_ep, party_ep) = duplex_pair(meter);
    let leader_eps = vec![leader_ep];

    let handle = std::thread::spawn(move || {
        let leader = dash::coordinator::Leader {
            endpoints: &leader_eps,
            cfg: &c,
            k: 4,
            m: 8,
            t: 1,
            session: 0,
        };
        leader.run(99)
    });

    // Play the party: consume SETUP + COMPRESS, send a valid base
    // round, then deliver shard 0 twice.
    let setup = Setup::from_frame(&party_ep.recv().unwrap()).unwrap();
    assert_eq!(setup.m, 8);
    Compress::from_frame(&party_ep.recv().unwrap()).unwrap();
    let cp = compress_party(&data.ys, &data.c, &data.x, 8, Some(1));
    party_ep
        .send(&PlainBase { flat: cp.base().flatten(), r: cp.r.clone() }.to_frame())
        .unwrap();
    let plan = ShardPlan::new(8, 4);
    let r0 = plan.range(0);
    let flat0 = cp.variant_block(r0.j0, r0.j1).flatten();
    party_ep.send(&PlainShard { shard: 0, flat: flat0.clone() }.to_frame()).unwrap();
    // re-delivery: the leader expects shard 1 next
    party_ep.send(&PlainShard { shard: 0, flat: flat0 }.to_frame()).unwrap();

    let err = handle.join().unwrap().unwrap_err();
    assert!(format!("{err:#}").contains("out of order"), "{err:#}");
    // the leader's failure reaches the party as a protocol ErrorMsg
    let f = party_ep.recv().unwrap();
    assert_eq!(f.tag, TAG_ERROR, "expected ERROR frame, got tag {}", f.tag);
    // and error frames built party-side still round-trip (sanity)
    assert_eq!(f.tag, error_frame("x").tag);
}
