//! Integration: protocol-level properties across backends — E5 exactness
//! (multi-party == pooled), E4 communication shape, privacy smoke checks,
//! and randomized property sweeps over cohort shapes.

use dash::coordinator::{run_multi_party_scan, run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, pool_cohort, CohortSpec};
use dash::linalg::rel_err;
use dash::mpc::Backend;
use dash::scan::{
    combine_compressed, compress_party, flatten_for_sum, unflatten_sum, CombineOptions,
    RFactorMethod, ScanConfig, ScanOutput,
};
use dash::util::proptest::{run_prop, PropConfig};
use dash::util::rng::Rng;

fn pooled_oracle(cohort: &dash::gwas::Cohort) -> ScanOutput {
    let pooled = pool_cohort(cohort);
    let cp = compress_party(&pooled.ys, &pooled.c, &pooled.x, 64, Some(2));
    let (layout, flat) = flatten_for_sum(&cp);
    let agg = unflatten_sum(layout, &flat).unwrap();
    combine_compressed(
        &agg,
        Some(std::slice::from_ref(&cp.r)),
        CombineOptions { r_method: RFactorMethod::Tsqr },
    )
    .unwrap()
}

fn spec_for(parties: usize, n_per: usize, m: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_per; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 3.min(m),
        effect_sd: 0.4,
        fst: 0.05,
        party_admixture: (0..parties)
            .map(|i| if parties == 1 { 0.5 } else { i as f64 / (parties - 1) as f64 })
            .collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

/// E5: exactness across party counts, plaintext backend (fp-exact path).
#[test]
fn e5_exactness_across_party_counts() {
    for parties in [1usize, 2, 3, 5] {
        let cohort = generate_cohort(&spec_for(parties, 120, 50), 500 + parties as u64);
        let cfg = ScanConfig {
            backend: Backend::Plaintext,
            block_m: 16,
            threads: Some(2),
            ..Default::default()
        };
        let res = run_multi_party_scan(&cohort, &cfg).unwrap();
        let oracle = pooled_oracle(&cohort);
        assert!(
            rel_err(&res.output.assoc[0].beta, &oracle.assoc[0].beta) < 1e-9,
            "P={parties} beta"
        );
        assert!(
            rel_err(&res.output.assoc[0].se, &oracle.assoc[0].se) < 1e-9,
            "P={parties} se"
        );
        // t and p too
        let finite: Vec<usize> =
            (0..cohort.m()).filter(|&j| oracle.assoc[0].p[j].is_finite()).collect();
        for &j in &finite {
            assert!((res.output.assoc[0].p[j] - oracle.assoc[0].p[j]).abs() < 1e-9, "p[{j}]");
        }
    }
}

/// E5 property sweep: random shapes, masked backend, fixed-point tolerance.
#[test]
fn e5_property_masked_random_shapes() {
    run_prop(
        "masked-matches-oracle",
        PropConfig { cases: 8, ..Default::default() },
        |r: &mut Rng| {
            let parties = 2 + r.below(3) as usize;
            let n_per = 60 + r.below(100) as usize;
            let m = 10 + r.below(40) as usize;
            (parties, n_per, m, r.next_u64())
        },
        |&(parties, n_per, m, seed)| {
            let cohort = generate_cohort(&spec_for(parties, n_per, m), seed);
            let cfg = ScanConfig {
                backend: Backend::Masked,
                block_m: 32,
                threads: Some(1),
                ..Default::default()
            };
            let res = run_multi_party_scan(&cohort, &cfg)
                .map_err(|e| format!("scan failed: {e:#}"))?;
            let oracle = pooled_oracle(&cohort);
            for j in 0..m {
                let (a, b) = (res.output.assoc[0].beta[j], oracle.assoc[0].beta[j]);
                if a.is_finite() && b.is_finite() && (a - b).abs() > 2e-4 * b.abs().max(1.0) {
                    return Err(format!("beta[{j}]: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// E4: per-party uplink bytes are O(M) — doubling M roughly doubles
/// bytes; increasing N leaves bytes unchanged.
#[test]
fn e4_communication_scaling_shape() {
    let cfg = ScanConfig { backend: Backend::Masked, block_m: 64, threads: Some(1), ..Default::default() };

    let bytes_for = |n_per: usize, m: usize| -> u64 {
        let cohort = generate_cohort(&spec_for(3, n_per, m), 600);
        let res = run_multi_party_scan(&cohort, &cfg).unwrap();
        res.metrics.bytes_total
    };

    let b_m200 = bytes_for(80, 200);
    let b_m400 = bytes_for(80, 400);
    let ratio = b_m400 as f64 / b_m200 as f64;
    assert!((1.6..=2.4).contains(&ratio), "M-scaling ratio {ratio}");

    // N independence: 4x samples, same M → identical protocol bytes
    let b_n_small = bytes_for(60, 200);
    let b_n_large = bytes_for(240, 200);
    assert_eq!(b_n_small, b_n_large, "bytes must not depend on N");
}

/// Privacy smoke: in masked mode the leader's transcript of a single
/// party contribution must not contain the party's plaintext statistics.
#[test]
fn masked_contribution_is_not_plaintext() {
    use dash::mpc::fixed::FixedCodec;
    use dash::mpc::masking::PairwiseMasker;

    let cohort = generate_cohort(&spec_for(3, 100, 30), 601);
    let p0 = &cohort.parties[0];
    let cp = compress_party(&p0.ys, &p0.c, &p0.x, 30, Some(1));
    let (_, flat) = flatten_for_sum(&cp);
    let codec = FixedCodec::default();
    let plain_enc = codec.encode_vec(&flat).unwrap();

    let mut rng = Rng::new(602);
    let seeds = PairwiseMasker::session_seeds(3, &mut rng);
    let mut masker = PairwiseMasker::new(0, 3, seeds[0].clone());
    let mut masked = plain_enc.clone();
    masker.mask_in_place(&mut masked);
    let unchanged = plain_enc.iter().zip(&masked).filter(|(a, b)| a == b).count();
    assert!(
        unchanged <= 2,
        "masked contribution leaks {unchanged} plaintext words"
    );
}

/// Heterogeneous party sizes, tail-block shapes, single-variant edge.
#[test]
fn uneven_parties_and_edge_shapes() {
    let spec = CohortSpec {
        party_sizes: vec![33, 190, 71],
        m_variants: 1,
        n_traits: 1,
        n_causal: 1,
        effect_sd: 0.6,
        fst: 0.02,
        party_admixture: vec![0.1, 0.4, 0.9],
        ancestry_effect: 0.2,
        batch_effect_sd: 0.0,
        n_pcs: 1,
        noise_sd: 1.0,
        binary_traits: false,
    };
    let cohort = generate_cohort(&spec, 603);
    let cfg = ScanConfig {
        backend: Backend::Plaintext,
        block_m: 7,
        threads: Some(3),
        ..Default::default()
    };
    let res = run_multi_party_scan(&cohort, &cfg).unwrap();
    let oracle = pooled_oracle(&cohort);
    assert!(rel_err(&res.output.assoc[0].beta, &oracle.assoc[0].beta) < 1e-9);
}

/// Shamir with a strict quorum gives the same answer as masked.
#[test]
fn shamir_quorum_equivalence() {
    let cohort = generate_cohort(&spec_for(5, 80, 25), 604);
    let masked = run_multi_party_scan(
        &cohort,
        &ScanConfig { backend: Backend::Masked, block_m: 25, threads: Some(1), ..Default::default() },
    )
    .unwrap();
    let shamir = run_multi_party_scan(
        &cohort,
        &ScanConfig {
            backend: Backend::Shamir { threshold: 3 },
            block_m: 25,
            threads: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    for j in 0..cohort.m() {
        let (a, b) = (masked.output.assoc[0].beta[j], shamir.output.assoc[0].beta[j]);
        if a.is_finite() && b.is_finite() {
            assert!((a - b).abs() < 1e-5 * b.abs().max(1.0), "beta[{j}]: {a} vs {b}");
        }
    }
}

/// TCP transport: full protocol over real sockets.
#[test]
fn tcp_transport_end_to_end() {
    let cohort = generate_cohort(&spec_for(3, 70, 20), 605);
    let cfg = ScanConfig { backend: Backend::Masked, block_m: 20, threads: Some(1), ..Default::default() };
    let res = run_multi_party_scan_t(&cohort, &cfg, Transport::Tcp, 77).unwrap();
    assert!(res.output.min_p_value().is_some());
    assert!(res.metrics.bytes_total > 0);
}
