//! Cross-backend conformance matrix (acceptance criteria of the
//! parameterized-artifact-suite tentpole): one scenario definition runs
//! across {plaintext, masked, Shamir} × {in-proc, TCP} × {Rust,
//! artifact} and every cell must reproduce the Rust baseline's scan +
//! SELECT statistics bit-for-bit, with the artifact suite executing
//! exactly one X-side pass per shard regardless of the trait count.
//! Also: the artifact-mode memory regression (peak resident block bytes
//! `O(shard_m·N_p)`, not `O(M·N_p)`) and lowering-cache behavior over
//! ragged shard plans.
//!
//! Scenarios with `sessions: N` additionally run N concurrent
//! multiplexed sessions over one shared connection pair per party in
//! every cell and hold each session to the same bit-identity contract
//! (see `tests/sessions.rs` for the 16-session TCP acceptance run).

mod common;

use common::{run_conformance, spec_for, Compute, Scenario};
use dash::coordinator::Transport;
use dash::gwas::generate_cohort;
use dash::mpc::Backend;
use dash::scan::{Glm, SelectPolicy};

// The acceptance grid: shard_m ∈ {7, 64, whole-M} × T ∈ {1, 16}, all
// three backends, Rust vs artifact, bit-identical.
conformance_scenarios! {
    scan_shard7_t1: { shard_m: 7, t: 1, cohort_seed: 0xA000 },
    scan_shard64_t1: { shard_m: 64, t: 1, cohort_seed: 0xA001 },
    scan_whole_m_t1: { shard_m: 0, t: 1, cohort_seed: 0xA002 },
    scan_shard7_t16: { shard_m: 7, t: 16, cohort_seed: 0xA003 },
    scan_shard64_t16: { shard_m: 64, t: 16, cohort_seed: 0xA004 },
    scan_whole_m_t16: { shard_m: 0, t: 16, cohort_seed: 0xA005 },
    // SELECT rounds through the matrix: gathered candidate round +
    // promote cross-product rounds, bit-identical picks everywhere
    select_union_t1: {
        shard_m: 16, t: 1, select_k: 2, select_candidates: 70, cohort_seed: 0xA006
    },
    select_per_trait_t4: {
        shard_m: 16, t: 4, select_k: 2, select_candidates: 16,
        select_policy: SelectPolicy::PerTrait, cohort_seed: 0xA007
    },
    // transport closure: TCP cells must match the in-proc baseline too
    tcp_spot_check: { shard_m: 16, t: 4, select_k: 1, tcp: true, cohort_seed: 0xA008 },
    // reactor closure: the epoll readiness-loop transport must reproduce
    // the in-proc baseline bit-for-bit, scan and SELECT alike
    reactor_spot_check: {
        shard_m: 16, t: 4, select_k: 1, reactor: true, cohort_seed: 0xA00E
    },
    // reactor × multiplexed sessions: concurrent sessions driven by one
    // readiness thread, each bit-identical to the serial baseline
    reactor_sessions_x4: {
        sessions: 4, shard_m: 16, t: 2, reactor: true, n_per: 24, m: 40,
        cohort_seed: 0xA00F
    },
    // session closure: concurrent multiplexed sessions over shared
    // connections, every session bit-identical to the serial baseline,
    // one shared artifact engine per party (no per-session recompiles)
    sessions_x4_scan: {
        sessions: 4, shard_m: 16, t: 2, n_per: 24, m: 40, cohort_seed: 0xA009
    },
    sessions_x4_select: {
        sessions: 4, shard_m: 8, t: 2, select_k: 1, select_candidates: 8,
        n_per: 24, m: 32, cohort_seed: 0xA00A
    },
    // threaded compress closure: the tiled kernels' canonical
    // accumulation order makes the worker-thread budget result-neutral,
    // so threaded cells hold the exact same cross-backend bit-identity
    // contract as the serial grid above
    scan_shard16_threads4: {
        shard_m: 16, t: 4, compress_threads: 4, cohort_seed: 0xA00B
    },
    scan_whole_m_t16_threads7: {
        shard_m: 0, t: 16, compress_threads: 7, cohort_seed: 0xA00C
    },
    select_union_threads4: {
        shard_m: 16, t: 1, select_k: 2, select_candidates: 70,
        compress_threads: 4, cohort_seed: 0xA00D
    },
    // logistic closure: the secure-IRLS scan holds the same
    // bit-identity contract across the whole matrix — every backend,
    // Rust vs artifact-reference compute, and the reactor transport —
    // with the artifact suite running one reweighted base pass per
    // Newton step, zero linear X-side passes, and one weighted shard
    // pass per shard at the final β
    logistic_whole_m: { glm: Glm::Logistic, t: 2, cohort_seed: 0xA010 },
    logistic_sharded_reactor: {
        glm: Glm::Logistic, shard_m: 16, t: 2, reactor: true, cohort_seed: 0xA011
    },
}

/// The X-side pass count is a function of the shard plan alone: a T=16
/// session costs exactly as many artifact X-side passes as a T=1
/// session over the same plan (the trait-batching amortization claim).
#[test]
fn xside_passes_independent_of_trait_count() {
    let mut counts = Vec::new();
    for t in [1usize, 16] {
        let sc = Scenario { shard_m: 16, t, cohort_seed: 0xA100, ..Default::default() };
        let cells = run_conformance(&sc);
        let (_, _, res) = cells
            .iter()
            .find(|(b, c, _)| *b == Backend::Masked && *c == Compute::Artifact)
            .expect("artifact cell present");
        counts.push(res.party_kernels[0].xside_passes());
    }
    assert_eq!(counts[0], counts[1], "X-side passes must not scale with T");
}

/// Memory regression: peak resident artifact block bytes in a sharded
/// session are set by the canonical shard width, not by M. With the
/// entry ladder starting at 64, a shard_m=16 session over M=1024 must
/// stay within the analytic `O(N_p · canon(shard_m))` bound and far
/// below the single-shot session's whole-M block.
#[test]
fn artifact_peak_block_bytes_bounded_by_shard_width() {
    let (parties, n_per, m, t) = (3usize, 50usize, 1024usize, 2usize);
    let spec = spec_for(parties, n_per, m, t);
    let k = spec.k_covariates();
    let cohort = generate_cohort(&spec, 0xA200);
    let run = |shard_m: usize| {
        common::run(
            &cohort,
            &common::cfg_compute(Backend::Masked, shard_m, Compute::Artifact),
            Transport::InProc,
            77,
        )
    };
    let sharded = run(16);
    let single = run(0);
    assert_eq!(sharded.metrics.shards, 64);
    assert_eq!(single.metrics.shards, 1);

    // Analytic bound per party: the widest resident block is the padded
    // CompressXy/CompressX working set — inputs N_p·(wc + t_pad + k_pad)
    // plus O((k_pad + t_pad)·wc) outputs, wc = canon(16) = 64,
    // t_pad = canon(2) = 4, k_pad = 16.
    let (wc, t_pad, k_pad) = (64u64, 4u64, 16u64);
    let n = n_per as u64;
    let bound = 8 * (n * (wc + t_pad + k_pad) + wc * t_pad + wc + k_pad * wc);
    for (p, km) in sharded.party_kernels.iter().enumerate() {
        let peak = km.peak_block_bytes();
        assert!(peak > 0, "party {p}: no artifact blocks metered");
        assert!(
            peak <= bound,
            "party {p}: peak block bytes {peak} exceed O(shard_m·N_p) bound {bound}"
        );
    }
    // ... while the single-shot session materializes the whole-M block
    // (canon(1024) = 1024 = 16× wider): the shard bound is really about
    // the shard width.
    let sharded_peak: u64 =
        sharded.party_kernels.iter().map(|k| k.peak_block_bytes()).max().unwrap();
    let single_peak: u64 =
        single.party_kernels.iter().map(|k| k.peak_block_bytes()).max().unwrap();
    assert!(
        sharded_peak * 4 <= single_peak,
        "sharded peak {sharded_peak} not far below whole-M peak {single_peak}"
    );
    // identical statistics regardless (sharding is a pure execution knob)
    common::assert_scan_bits_eq(&sharded, &single, "sharded vs single-shot artifact");
    // K must fit the default entry padding for the bound above to hold
    assert!(k as u64 <= k_pad);
}

/// A ragged shard plan (tail narrower than shard_m, both below the
/// first ladder rung) canonicalizes onto a handful of lowered entries:
/// the cache, not the shard count, bounds lowering work.
#[test]
fn lowering_cache_covers_ragged_plans() {
    let cohort = generate_cohort(&spec_for(3, 40, 70, 3), 0xA300);
    let res = common::run(
        &cohort,
        &common::cfg_compute(Backend::Masked, 7, Compute::Artifact),
        Transport::InProc,
        78,
    );
    assert_eq!(res.metrics.shards, 10);
    for (p, km) in res.party_kernels.iter().enumerate() {
        // one CompressXy entry + one canonical CompressX entry (all ten
        // shards, including the 7-wide tail, round up to w=64)
        assert_eq!(km.lowered_entries(), 2, "party {p}: lowered entries");
        assert_eq!(km.xside_passes(), 10, "party {p}: X-side passes");
        assert_eq!(km.cache_hits(), 9, "party {p}: cache hits");
    }
}

/// The `compress_threads` knob is a pure execution knob: any thread
/// budget must reproduce the single-threaded session's scan + SELECT
/// statistics bit-for-bit, across every backend and both compute paths
/// (the tiled kernels fold per-tile partials in canonical tile order,
/// which is independent of the thread count).
#[test]
fn threaded_compress_matches_serial_e2e() {
    let cohort = generate_cohort(&spec_for(3, 40, 70, 4), 0xA500);
    for backend in common::backends() {
        for compute in Compute::all() {
            let run_with = |threads: usize| {
                let mut cfg = common::cfg_compute(backend, 16, compute);
                cfg.select_k = 1;
                cfg.compress_threads = Some(threads);
                common::run(&cohort, &cfg, Transport::InProc, 80)
            };
            let serial = run_with(1);
            for threads in [2usize, 4, 7] {
                let threaded = run_with(threads);
                let label =
                    format!("compress_threads={threads} [{backend:?} × {compute:?}]");
                common::assert_scan_bits_eq(&threaded, &serial, &label);
                common::assert_select_bits_eq(&threaded, &serial, &label);
            }
        }
    }
}

/// Rust-path sessions carry zeroed kernel telemetry — the meters are
/// session plumbing, not artifact-path-only state.
#[test]
fn rust_sessions_have_inert_kernel_meters() {
    let cohort = generate_cohort(&spec_for(3, 40, 24, 1), 0xA400);
    let res = common::run_inproc(&cohort, Backend::Masked, 8, 79);
    assert_eq!(res.party_kernels.len(), 3);
    for km in &res.party_kernels {
        assert_eq!(km.lowered_entries(), 0);
        assert_eq!(km.xside_passes(), 0);
        assert_eq!(km.peak_block_bytes(), 0);
    }
}
