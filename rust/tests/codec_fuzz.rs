//! Property-style codec fuzzing: randomized `WireMessage`s (every
//! variant, including the SELECT frames and degenerate empty shapes)
//! must (a) round-trip the binary codec bit-exactly, (b) agree
//! semantically between the binary and JSON-debug codecs, and (c) fail
//! *cleanly* — an `Err`, never a panic — on truncated or bit-flipped
//! frames.

use dash::coordinator::messages::*;
use dash::linalg::Matrix;
use dash::net::{Codec, Frame, FrameDecoder, FrameReader, FrameWriter, WireMessage,
    FRAME_V2_MAGIC, SESSION_CTRL};
use dash::util::rng::Rng;

fn rand_u64s(rng: &mut Rng, max: usize) -> Vec<u64> {
    let n = (rng.next_u64() as usize) % (max + 1);
    (0..n).map(|_| rng.next_u64()).collect()
}

/// Arbitrary f64 bit patterns with NaNs canonicalized: the JSON-debug
/// codec is lossless for every value Rust can *format* distinctly
/// (±0.0, subnormals, infinities, shortest-round-trip decimals); NaN
/// payload bits have no textual form, so all NaNs print as `NaN`.
fn rand_f64s(rng: &mut Rng, max: usize) -> Vec<f64> {
    let n = (rng.next_u64() as usize) % (max + 1);
    (0..n)
        .map(|_| {
            let v = f64::from_bits(rng.next_u64());
            if v.is_nan() {
                f64::NAN
            } else {
                v
            }
        })
        .collect()
}

fn rand_f64(rng: &mut Rng) -> f64 {
    let v = f64::from_bits(rng.next_u64());
    if v.is_nan() {
        f64::NAN
    } else {
        v
    }
}

/// Round-trip + truncation + corruption battery for one message.
fn check<M: WireMessage>(m: &M, rng: &mut Rng) {
    // binary round-trip, compared by re-encoding (bit-exact even for
    // messages whose f64s break PartialEq)
    let f = m.to_frame();
    let back = M::from_frame(&f).expect("binary decode of a valid frame");
    assert_eq!(back.to_frame(), f, "binary re-encode mismatch");

    // binary ↔ JSON-debug semantic equality
    let js = Codec::JsonDebug.encode(m);
    let jback: M = Codec::JsonDebug.decode(&js).expect("json decode of a valid frame");
    assert_eq!(jback.to_frame(), f, "json↔binary semantic mismatch");

    // strict truncation ⇒ clean Err (length prefixes inside the payload
    // are unchanged, so some read must run off the end)
    if !f.payload.is_empty() {
        for _ in 0..4 {
            let cut = (rng.next_u64() as usize) % f.payload.len();
            let mut t = f.clone();
            t.payload.truncate(cut);
            assert!(M::from_frame(&t).is_err(), "truncated frame decoded");
        }
        // bit flips ⇒ no panic (Ok or Err both fine: a flipped f64 is
        // still a valid f64, a flipped length prefix must error)
        for _ in 0..8 {
            let mut cbin = f.clone();
            let i = (rng.next_u64() as usize) % cbin.payload.len();
            cbin.payload[i] ^= 1 << (rng.next_u64() % 8);
            let _ = M::from_frame(&cbin);
        }
    }
    // corrupted JSON text ⇒ no panic
    if !js.payload.is_empty() {
        for _ in 0..4 {
            let mut cjs = js.clone();
            let i = (rng.next_u64() as usize) % cjs.payload.len();
            cjs.payload[i] ^= 1 << (rng.next_u64() % 8);
            let _ = Codec::JsonDebug.decode::<M>(&cjs);
        }
    }
}

#[test]
fn fuzz_all_wire_messages() {
    let mut rng = Rng::new(0xC0DEC);
    for iter in 0..150u64 {
        let r = &mut rng;

        // done_shards must be strictly increasing (resume contract)
        let mut done_shards = rand_u64s(r, 6);
        done_shards.sort_unstable();
        done_shards.dedup();
        check(
            &Setup {
                session: r.next_u64(),
                party_index: r.next_u64(),
                parties: r.next_u64(),
                backend: r.next_u64() % 4,
                shamir_threshold: r.next_u64(),
                frac_bits: r.next_u64() % 64,
                k: r.next_u64(),
                m: r.next_u64(),
                t: r.next_u64(),
                block_m: r.next_u64(),
                shard_m: r.next_u64(),
                select_k: r.next_u64(),
                glm: r.next_u64() % 2,
                seeds: rand_u64s(r, 8), // incl. the 0-seed degenerate
                done_shards,
            },
            r,
        );
        check(&Compress, r);
        check(&Shutdown, r);

        // PlainBase: square R of side 0..=3 (side 0 = K=0 degenerate)
        let k = (r.next_u64() as usize) % 4;
        let r_data: Vec<f64> = (0..k * k).map(|_| rand_f64(r)).collect();
        check(
            &PlainBase { flat: rand_f64s(r, 12), r: Matrix::from_vec(k, k, r_data) },
            r,
        );
        check(&MaskedBase { enc: rand_u64s(r, 16) }, r);
        check(&PlainShard { shard: r.next_u64(), flat: rand_f64s(r, 16) }, r);
        check(&MaskedShard { shard: r.next_u64(), enc: rand_u64s(r, 16) }, r);

        let shares: Vec<Vec<u64>> =
            (0..(r.next_u64() as usize) % 4).map(|_| rand_u64s(r, 6)).collect();
        check(&ShamirOut { round: r.next_u64(), shares: shares.clone() }, r);
        check(&ShamirIn { round: r.next_u64(), shares }, r);
        check(&ShamirSum { round: r.next_u64(), sum: rand_u64s(r, 16) }, r);

        // ShardResult: trait-major, width possibly 0 (the T-adjacent
        // degenerate shapes)
        let traits = 1 + (r.next_u64() % 3);
        let w = (r.next_u64() as usize) % 5;
        let len = w * traits as usize;
        let beta: Vec<f64> = (0..len).map(|_| rand_f64(r)).collect();
        let se: Vec<f64> = (0..len).map(|_| rand_f64(r)).collect();
        check(&ShardResult { shard: r.next_u64(), j0: r.next_u64(), traits, beta, se }, r);

        // SELECT frames: strictly-increasing candidates (possibly empty)
        let mut cand = rand_u64s(r, 10);
        cand.sort_unstable();
        cand.dedup();
        check(
            &SelectSetup {
                k: r.next_u64(),
                policy: r.next_u64() % 2,
                lanes: 1 + r.next_u64() % 5,
                p_enter: rand_f64(r),
                candidates: cand,
            },
            r,
        );
        // Promote: ≥ 1 active lane
        let mut variants = rand_u64s(r, 4);
        variants.push(r.next_u64() % 1000); // guaranteed active (≠ MAX)
        check(&Promote { round: 1 + r.next_u64() % 100, variants }, r);
        check(&SelectDone { rounds: r.next_u64() }, r);
        let lanes = (r.next_u64() as usize) % 4; // 0-lane degenerate incl.
        let sr = SelectResult {
            round: r.next_u64(),
            variants: (0..lanes).map(|_| r.next_u64()).collect(),
            traits: (0..lanes).map(|_| r.next_u64()).collect(),
            beta: (0..lanes).map(|_| rand_f64(r)).collect(),
            se: (0..lanes).map(|_| rand_f64(r)).collect(),
            p: (0..lanes).map(|_| rand_f64(r)).collect(),
        };
        check(&sr, r);

        // Checkpoint: the decode validates its invariants, so the fuzz
        // inputs must honor them — version pinned, t ≥ 1, stats exactly
        // 4·t·m, done strictly increasing (possibly empty)
        let ck_t = 1 + r.next_u64() % 3;
        let ck_m = r.next_u64() % 5;
        let mut ck_done = rand_u64s(r, 6);
        ck_done.sort_unstable();
        ck_done.dedup();
        let ck_stats: Vec<f64> =
            (0..4 * ck_t as usize * ck_m as usize).map(|_| rand_f64(r)).collect();
        check(
            &Checkpoint {
                version: CHECKPOINT_VERSION,
                session: r.next_u64(),
                seed: r.next_u64(),
                backend: r.next_u64() % 4,
                m: ck_m,
                k: r.next_u64(),
                t: ck_t,
                shard_m: r.next_u64(),
                select_k: r.next_u64(),
                done: ck_done,
                df: if iter % 4 == 0 { f64::NAN } else { rand_f64(r) },
                stats: ck_stats,
            },
            r,
        );

        // IRLS frames: the decode validates its invariants (1-based
        // iterations, finite iterates, positive finite tolerance), so
        // the fuzz inputs must honor them
        check(
            &IrlsSetup {
                max_iter: 1 + r.next_u64() % 1000,
                tol: (1 + r.next_u64() % 1_000_000) as f64 * 1e-9,
            },
            r,
        );
        let tk = (r.next_u64() as usize) % 9; // incl. the empty degenerate
        let finite_beta = |r: &mut Rng| -> Vec<f64> {
            (0..tk).map(|_| (r.next_u64() % 2001) as f64 / 13.0 - 77.0).collect()
        };
        let beta = finite_beta(r);
        check(&IrlsRound { iter: 1 + r.next_u64() % 1000, beta }, r);
        let beta = finite_beta(r);
        check(&IrlsDone { iters: 1 + r.next_u64() % 1000, beta }, r);

        let msg: String = match iter % 3 {
            0 => String::new(),
            1 => "plain ascii error".to_string(),
            _ => "üñïçødé → boom 💥".to_string(),
        };
        check(&ErrorMsg { msg }, r);
    }
}

/// Cross-tag confusion: every frame decoded as every *other* message
/// type must error cleanly on the tag check.
#[test]
fn fuzz_wrong_tag_always_clean_error() {
    let mut rng = Rng::new(0x7A6);
    let frames = vec![
        Setup {
            session: 4,
            party_index: 0,
            parties: 2,
            backend: 1,
            shamir_threshold: 0,
            frac_bits: 24,
            k: 3,
            m: 5,
            t: 1,
            block_m: 4,
            shard_m: 0,
            select_k: 2,
            glm: 0,
            seeds: vec![1, 2],
            done_shards: vec![],
        }
        .to_frame(),
        Compress.to_frame(),
        PlainShard { shard: 0, flat: vec![1.0] }.to_frame(),
        SelectSetup { k: 1, policy: 0, lanes: 1, p_enter: 0.5, candidates: vec![3] }
            .to_frame(),
        Promote { round: 1, variants: vec![3] }.to_frame(),
        SelectDone { rounds: 1 }.to_frame(),
        error_frame("x"),
    ];
    for f in &frames {
        // decode under a deliberately wrong type for each
        if f.tag != TAG_SETUP {
            assert!(Setup::from_frame(f).is_err());
        }
        if f.tag != TAG_PROMOTE {
            assert!(Promote::from_frame(f).is_err());
        }
        if f.tag != TAG_SELECT_RESULT {
            assert!(SelectResult::from_frame(f).is_err());
        }
        if f.tag != TAG_MASKED_SHARD {
            assert!(MaskedShard::from_frame(f).is_err());
        }
    }
    // and a randomized tag sweep over one payload must never panic
    let base = PlainShard { shard: 7, flat: vec![0.5, -0.5] }.to_frame();
    for _ in 0..64 {
        let mut f = base.clone();
        f.tag = (rng.next_u64() % 32) as u32;
        let _ = Setup::from_frame(&f);
        let _ = ShardResult::from_frame(&f);
        let _ = SelectSetup::from_frame(&f);
        let _ = ErrorMsg::from_frame(&f);
    }
}

/// Random session id for v2 fuzzing, biased toward the interesting
/// extremes (0, the control session, near-MAX).
fn rand_sid(rng: &mut Rng) -> u64 {
    match rng.next_u64() % 5 {
        0 => 0,
        1 => SESSION_CTRL,
        2 => u64::MAX - 1,
        _ => rng.next_u64(),
    }
}

/// v2 framing: random mixed v1/v2 streams round-trip through
/// `read_any` with exact session-id and payload fidelity, and
/// truncations fail cleanly.
#[test]
fn fuzz_v2_framing_roundtrip_and_v1_fallback() {
    let mut rng = Rng::new(0xF2A3);
    for _ in 0..60 {
        let n = 1 + (rng.next_u64() as usize) % 8;
        let mut expected: Vec<(u64, Frame)> = Vec::with_capacity(n);
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            for _ in 0..n {
                let mut f = Frame::new((rng.next_u64() % 1000) as u32);
                let words = (rng.next_u64() as usize) % 6;
                for _ in 0..words {
                    f.put_u64(rng.next_u64());
                }
                if rng.next_u64() % 2 == 0 {
                    let sid = rand_sid(&mut rng);
                    let wrote = w.write_v2(sid, &f).unwrap();
                    assert_eq!(wrote, f.wire_len_v2());
                    expected.push((sid, f));
                } else {
                    w.write(&f).unwrap();
                    expected.push((0, f)); // v1 fallback session
                }
            }
        }
        let mut r = FrameReader::new(buf.as_slice());
        for (want_sid, want_f) in &expected {
            let (sid, f) = r.read_any().unwrap();
            assert_eq!(sid, *want_sid);
            assert_eq!(&f, want_f);
        }
        assert!(r.read_any().is_err(), "stream must be exhausted");

        // strict truncation anywhere ⇒ some read errors cleanly, the
        // reads before it are intact, and nothing panics
        if buf.len() > 1 {
            let cut = 1 + (rng.next_u64() as usize) % (buf.len() - 1);
            let t = &buf[..cut];
            let mut r = FrameReader::new(t);
            let mut decoded = 0usize;
            loop {
                match r.read_any() {
                    Ok((sid, f)) => {
                        assert_eq!(sid, expected[decoded].0);
                        assert_eq!(f, expected[decoded].1);
                        decoded += 1;
                    }
                    Err(_) => break,
                }
            }
            assert!(decoded < expected.len(), "truncated stream decoded fully");
        }
    }
}

/// Encode a random mixed v1/v2 stream, returning the wire bytes, the
/// `(session, frame)` sequence `read_any` (and the incremental decoder)
/// must reproduce from them, and each frame's on-wire byte length.
fn rand_stream(rng: &mut Rng) -> (Vec<u8>, Vec<(u64, Frame)>, Vec<u64>) {
    let n = 1 + (rng.next_u64() as usize) % 10;
    let mut expected: Vec<(u64, Frame)> = Vec::with_capacity(n);
    let mut lens: Vec<u64> = Vec::with_capacity(n);
    let mut buf = Vec::new();
    let mut w = FrameWriter::new(&mut buf);
    for _ in 0..n {
        let mut f = Frame::new((rng.next_u64() % 1000) as u32);
        for _ in 0..(rng.next_u64() as usize) % 6 {
            f.put_u64(rng.next_u64());
        }
        if rng.next_u64() % 2 == 0 {
            let sid = rand_sid(rng);
            lens.push(w.write_v2(sid, &f).unwrap());
            expected.push((sid, f));
        } else {
            lens.push(w.write(&f).unwrap());
            expected.push((0, f)); // v1 fallback session
        }
    }
    drop(w);
    (buf, expected, lens)
}

/// Drain every currently-decodable frame from the incremental decoder.
fn drain(dec: &mut FrameDecoder) -> Vec<(u64, Frame)> {
    let mut out = Vec::new();
    while let Some(sf) = dec.next_frame().expect("valid stream must decode cleanly") {
        out.push(sf);
    }
    out
}

/// The incremental decoder the reactor feeds from arbitrary readiness
/// chunks must reassemble mixed v1/v2 streams exactly: byte-at-a-time
/// delivery (the worst partial-read case) and random-split delivery
/// both reproduce the `read_any` frame sequence bit-for-bit, with no
/// bytes left buffered at stream end.
#[test]
fn fuzz_incremental_decoder_reassembles_any_split() {
    let mut rng = Rng::new(0xDECA_0DE5);
    for round in 0..60u64 {
        let (buf, expected, _) = rand_stream(&mut rng);

        // byte-at-a-time: every push is a 1-byte partial read
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in &buf {
            dec.push(&[b]);
            got.extend(drain(&mut dec));
        }
        assert_eq!(got, expected, "round {round}: byte-at-a-time reassembly");
        assert_eq!(dec.buffered_len(), 0, "round {round}: residual bytes");

        // random splits: chunk boundaries land anywhere, including
        // mid-header and mid-payload
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut pos = 0usize;
        while pos < buf.len() {
            let take = 1 + (rng.next_u64() as usize) % (buf.len() - pos);
            dec.push(&buf[pos..pos + take]);
            pos += take;
            got.extend(drain(&mut dec));
        }
        assert_eq!(got, expected, "round {round}: random-split reassembly");
        assert_eq!(dec.buffered_len(), 0, "round {round}: residual bytes");
    }
}

/// Truncation through the incremental decoder is *visible*, never
/// silent: a stream cut mid-frame yields only the frames before the
/// cut and leaves the partial frame buffered (`buffered_len > 0`) — the
/// reactor's EOF-mid-frame detection hinges on exactly this signal.
/// Corrupted headers (an implausible length word) fail with an Err,
/// not a panic or an unbounded buffer.
#[test]
fn fuzz_incremental_decoder_truncation_and_corruption() {
    let mut rng = Rng::new(0xDECA_0DE6);
    for round in 0..60u64 {
        let (buf, expected, lens) = rand_stream(&mut rng);

        // cut strictly inside the stream, then feed byte-at-a-time
        let cut = 1 + (rng.next_u64() as usize) % (buf.len() - 1);
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in &buf[..cut] {
            dec.push(&[b]);
            got.extend(drain(&mut dec));
        }
        assert!(got.len() < expected.len(), "round {round}: truncated stream complete");
        assert_eq!(got[..], expected[..got.len()], "round {round}: prefix fidelity");
        // bytes past the last whole frame must stay visibly buffered —
        // the reactor's EOF-mid-frame detection hinges on this signal
        let consumed: u64 = lens[..got.len()].iter().sum();
        assert_eq!(
            dec.buffered_len() as u64,
            cut as u64 - consumed,
            "round {round}: partial-frame bytes unaccounted"
        );

        // corrupt the length word of the first frame to an implausible
        // value: the decoder must reject it cleanly
        let mut bad = buf.clone();
        let len_off = if u32::from_le_bytes(bad[0..4].try_into().unwrap())
            == FRAME_V2_MAGIC
        {
            16 // [magic][session][tag][len]
        } else {
            4 // [tag][len]
        };
        bad[len_off..len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&bad);
        assert!(
            dec.next_frame().is_err(),
            "round {round}: implausible length accepted"
        );
    }
}

/// A v1 frame whose length word is smashed to an implausible value must
/// fail `FrameReader::read_any` with a clean Err — the blocking-reader
/// twin of the incremental-decoder guard above. Before the uniform
/// length guard, a huge v1 length word turned into an attempted
/// multi-exabyte allocation instead of an error.
#[test]
fn fuzz_v1_implausible_length_is_a_clean_read_error() {
    let mut rng = Rng::new(0xBAD_1E4);
    for _ in 0..40 {
        let mut f = Frame::new((rng.next_u64() % 1000) as u32);
        for _ in 0..(rng.next_u64() as usize) % 6 {
            f.put_u64(rng.next_u64());
        }
        let mut buf = Vec::new();
        FrameWriter::new(&mut buf).write(&f).unwrap(); // v1: [tag][len][payload]
        // smash the v1 length word to a huge value (top bit set keeps it
        // above any plausible frame cap regardless of the low bits)
        let huge = rng.next_u64() | (1 << 62);
        buf[4..12].copy_from_slice(&huge.to_le_bytes());
        assert!(
            FrameReader::new(buf.as_slice()).read_any().is_err(),
            "implausible v1 length accepted by read_any"
        );
        // …and through the plain v1 read path too
        assert!(
            FrameReader::new(buf.as_slice()).read().is_err(),
            "implausible v1 length accepted by read"
        );
    }
}

/// A protocol message carried inside a v2 frame survives the session
/// envelope byte-for-byte — the envelope is pure framing.
#[test]
fn v2_envelope_is_transparent_to_the_codec_layer() {
    let mut rng = Rng::new(0xE57);
    for _ in 0..40 {
        let msg = MaskedShard {
            shard: rng.next_u64(),
            enc: (0..(rng.next_u64() as usize) % 16).map(|_| rng.next_u64()).collect(),
        };
        let f = msg.to_frame();
        let mut buf = Vec::new();
        let sid = rand_sid(&mut rng);
        FrameWriter::new(&mut buf).write_v2(sid, &f).unwrap();
        // the v2 magic word leads the stream…
        assert_eq!(u32::from_le_bytes(buf[0..4].try_into().unwrap()), FRAME_V2_MAGIC);
        // …and the decoded frame yields the identical message
        let (got_sid, got) = FrameReader::new(buf.as_slice()).read_any().unwrap();
        assert_eq!(got_sid, sid);
        assert_eq!(MaskedShard::from_frame(&got).unwrap(), msg);
    }
}
