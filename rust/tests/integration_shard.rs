//! Integration: the sharded streaming pipeline — exactness of the
//! shard decomposition across all three MPC backends, bounded per-round
//! communication, and transport equivalence (acceptance criteria of the
//! shard-pipeline tentpole).

mod common;

use common::{assert_bits_eq, backends, cfg};
use dash::coordinator::{run_multi_party_scan_t, MultiPartyScanResult, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::ShardPlan;

fn spec_for(parties: usize, n_per: usize, m: usize) -> CohortSpec {
    common::spec_for(parties, n_per, m, 1)
}

fn run(
    cohort: &dash::gwas::Cohort,
    backend: Backend,
    shard_m: usize,
    seed: u64,
) -> MultiPartyScanResult {
    common::run_inproc(cohort, backend, shard_m, seed)
}

/// Acceptance: a sharded scan over ≥ 4 shards produces an output
/// identical to the single-shot path for all three backends.
#[test]
fn sharded_matches_single_shot_all_backends() {
    let m = 64;
    let width = 16; // 4 shards
    assert_eq!(ShardPlan::new(m, width).count(), 4);
    let cohort = generate_cohort(&spec_for(3, 90, m), 700);
    for backend in backends() {
        let single = run(&cohort, backend, 0, 41);
        let sharded = run(&cohort, backend, width, 41);
        assert_eq!(single.metrics.shards, 1, "{backend:?}");
        assert_eq!(sharded.metrics.shards, 4, "{backend:?}");
        assert_bits_eq(&sharded.output.assoc[0].beta, &single.output.assoc[0].beta, "beta");
        assert_bits_eq(&sharded.output.assoc[0].se, &single.output.assoc[0].se, "se");
        assert_bits_eq(&sharded.output.assoc[0].p, &single.output.assoc[0].p, "p");
        assert_eq!(sharded.output.n, single.output.n);
        // covariate fit comes from the (identical) base round
        assert_bits_eq(
            &sharded.output.covariate_fit[0].gamma,
            &single.output.covariate_fit[0].gamma,
            "gamma",
        );
    }
}

/// Shard width is a pure execution parameter: any width (including a
/// ragged tail and width > M) reproduces the same answer.
#[test]
fn shard_width_invariance() {
    let m = 100;
    let cohort = generate_cohort(&spec_for(3, 80, m), 701);
    let baseline = run(&cohort, Backend::Masked, 0, 42);
    for width in [7usize, 16, 33, 100, 4096] {
        let res = run(&cohort, Backend::Masked, width, 42);
        assert_eq!(res.metrics.shards, ShardPlan::new(m, width).count(), "width {width}");
        assert_bits_eq(&res.output.assoc[0].beta, &baseline.output.assoc[0].beta, "beta");
        assert_bits_eq(&res.output.assoc[0].se, &baseline.output.assoc[0].se, "se");
    }
}

/// Acceptance: peak payload bytes per contribution round are bounded by
/// the shard width, not by total M.
#[test]
fn peak_round_bytes_bounded_by_shard_width() {
    let m = 256;
    let cohort = generate_cohort(&spec_for(3, 70, m), 702);
    let single = run(&cohort, Backend::Masked, 0, 43);
    let sharded = run(&cohort, Backend::Masked, 32, 43);
    assert_eq!(sharded.metrics.shards, 8);
    assert!(single.metrics.bytes_max_round > 0);
    // 8× narrower rounds → ≥ 4× smaller peak round (framing overhead
    // keeps it from the full 8×)
    assert!(
        sharded.metrics.bytes_max_round * 4 <= single.metrics.bytes_max_round,
        "peak round bytes not bounded: sharded {} vs single-shot {}",
        sharded.metrics.bytes_max_round,
        single.metrics.bytes_max_round
    );
    // total bytes stay within a few percent (same statistics + per-shard
    // framing)
    let (a, b) = (sharded.metrics.bytes_total as f64, single.metrics.bytes_total as f64);
    assert!(a / b < 1.1, "sharding blew up total bytes: {a} vs {b}");
}

/// The sharded protocol is byte-identical across transports: an in-proc
/// session and a TCP session serialize exactly the same frames.
#[test]
fn tcp_and_inproc_sessions_byte_identical() {
    let cohort = generate_cohort(&spec_for(3, 60, 48), 703);
    let cfg = cfg(Backend::Masked, 12); // 4 shards
    let inproc = run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 44).unwrap();
    // TCP contends for sockets with the parallel test suite; allow one
    // retry before judging (byte accounting itself is deterministic).
    let mut last_err = String::new();
    for _attempt in 0..2 {
        let tcp = run_multi_party_scan_t(&cohort, &cfg, Transport::Tcp, 44).unwrap();
        if tcp.metrics.bytes_total == inproc.metrics.bytes_total
            && tcp.metrics.messages_total == inproc.metrics.messages_total
        {
            assert_bits_eq(&tcp.output.assoc[0].beta, &inproc.output.assoc[0].beta, "beta");
            assert_eq!(tcp.metrics.shards, inproc.metrics.shards);
            return;
        }
        last_err = format!(
            "bytes {} vs {}, messages {} vs {}",
            tcp.metrics.bytes_total,
            inproc.metrics.bytes_total,
            tcp.metrics.messages_total,
            inproc.metrics.messages_total
        );
    }
    panic!("tcp/in-proc transcript mismatch after retry: {last_err}");
}

/// Shamir with a strict quorum agrees with masked through the sharded
/// path (fixed-point tolerance — different ring/field encodings).
#[test]
fn sharded_shamir_quorum_matches_masked() {
    let cohort = generate_cohort(&spec_for(5, 60, 40), 704);
    let masked = run(&cohort, Backend::Masked, 10, 45);
    let shamir = run(&cohort, Backend::Shamir { threshold: 3 }, 10, 45);
    for j in 0..40 {
        let (a, b) = (masked.output.assoc[0].beta[j], shamir.output.assoc[0].beta[j]);
        if a.is_finite() && b.is_finite() {
            assert!((a - b).abs() < 1e-5 * b.abs().max(1.0), "beta[{j}]: {a} vs {b}");
        }
    }
}

/// Single-variant and single-party edge shapes survive sharding.
#[test]
fn edge_shapes_sharded() {
    // M = 1 with a wide shard plan → one 1-column shard
    let cohort = generate_cohort(&spec_for(2, 50, 1), 705);
    let res = run(&cohort, Backend::Masked, 64, 46);
    assert_eq!(res.metrics.shards, 1);
    assert_eq!(res.output.assoc[0].beta.len(), 1);

    // single party, 3 shards
    let cohort1 = generate_cohort(&spec_for(1, 80, 12), 706);
    let single = run(&cohort1, Backend::Plaintext, 0, 47);
    let sharded = run(&cohort1, Backend::Plaintext, 4, 47);
    assert_bits_eq(&sharded.output.assoc[0].beta, &single.output.assoc[0].beta, "beta");
}

/// Every party receives the same assembled per-shard results it would
/// have gotten from a single RESULT broadcast (checked implicitly by the
/// protocol: width/order mismatches fail the session).
#[test]
fn metrics_reflect_shard_plan() {
    let cohort = generate_cohort(&spec_for(3, 60, 30), 707);
    let res = run(&cohort, Backend::Masked, 8, 48);
    assert_eq!(res.metrics.shards, 4);
    assert!(res.metrics.bytes_result > 0);
    assert!(res.metrics.bytes_max_round > 0);
    assert!(res.metrics.bytes_total >= res.metrics.bytes_result);
    assert_eq!(res.party_bytes.len(), 3);
    assert!(res.party_bytes.iter().all(|&b| b > 0));
}
