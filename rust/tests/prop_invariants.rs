//! Property-based invariants (driven by `dash::util::proptest`):
//!
//! - `qr_append` / `project_append` reproduce a full re-factorization of
//!   the appended basis, for random shapes;
//! - flatten → secure-sum → unflatten is the *identity* on the
//!   elementwise aggregate for fixed-point-representable inputs, across
//!   all three backends (losslessness of the wire encoding, not just
//!   closeness);
//! - the tiled compress kernels are bit-identical to their serial run
//!   for any (shape, trait count, tile height, thread budget) — the
//!   canonical ascending-tile fold makes the worker count invisible.

use dash::linalg::{householder_qr, project_append, qr_append, Matrix};
use dash::mpc::field::Fe;
use dash::mpc::fixed::FixedCodec;
use dash::mpc::masking::{aggregate_masked, PairwiseMasker};
use dash::mpc::shamir;
use dash::scan::{
    compress_variant_block_opts, compress_yside, flatten_for_sum, unflatten_sum,
    CompressedParty,
};
use dash::util::proptest::{all_close, fixed_repr_vec, run_prop, PropConfig};
use dash::util::rng::Rng;

fn hstack_col(a: &Matrix, col: Vec<f64>) -> Matrix {
    Matrix::vstack(&[&a.transpose(), &Matrix::from_col(col).transpose()]).transpose()
}

fn random_basis(rng: &mut Rng, n: usize, k: usize) -> Matrix {
    let mut c = Matrix::randn(n, k, rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    c
}

/// `qr_append(R, Qᵀb, b·b)` equals the R factor of a full Householder
/// re-factorization of `[C | b]`, for random (n, k).
#[test]
fn prop_qr_append_equals_full_refactorization() {
    run_prop(
        "qr-append-vs-full",
        PropConfig { cases: 48, ..Default::default() },
        |rng| {
            let n = 12 + rng.below(40) as usize;
            let k = 2 + rng.below(5) as usize;
            let c = random_basis(rng, n, k);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (c, b)
        },
        |(c, b)| {
            let f = householder_qr(c);
            let u = f.q.t_matvec(b);
            let d: f64 = b.iter().map(|v| v * v).sum();
            let r_app = qr_append(&f.r, &u, d)
                .map_err(|e| format!("append rejected a random column: {e:#}"))?;
            let full = householder_qr(&hstack_col(c, b.clone())).r;
            all_close(&r_app.data, &full.data, 1e-8)
        },
    );
}

/// `project_append` extends `QᵀX` by exactly the row a full
/// re-factorization would produce, for every projected column.
#[test]
fn prop_project_append_equals_full_projection() {
    run_prop(
        "project-append-vs-full",
        PropConfig { cases: 48, ..Default::default() },
        |rng| {
            let n = 15 + rng.below(30) as usize;
            let k = 2 + rng.below(4) as usize;
            let h = 1 + rng.below(6) as usize;
            let c = random_basis(rng, n, k);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xs = Matrix::randn(n, h, rng);
            (c, b, xs)
        },
        |(c, b, xs)| {
            let k = c.cols;
            let f = householder_qr(c);
            let u = f.q.t_matvec(b);
            let d: f64 = b.iter().map(|v| v * v).sum();
            let r_app =
                qr_append(&f.r, &u, d).map_err(|e| format!("append rejected: {e:#}"))?;
            let rho = r_app[(k, k)];
            let qt_x = f.q.t_matmul(xs);
            let full = householder_qr(&hstack_col(c, b.clone()));
            let qt_x_full = full.q.t_matmul(xs);
            for j in 0..xs.cols {
                let btx: f64 =
                    b.iter().zip(xs.col(j)).map(|(p, q)| p * q).sum();
                let inc = project_append(&u, rho, &qt_x.col(j), btx);
                // the positive-diagonal convention pins the appended
                // basis direction, so the signs must agree too
                let want = qt_x_full[(k, j)];
                if (inc - want).abs() > 1e-8 * want.abs().max(1.0) {
                    return Err(format!("col {j}: incremental {inc} vs full {want}"));
                }
            }
            Ok(())
        },
    );
}

/// Threaded compress is bit-identical to the single-threaded run with
/// the same tile height, for random shapes across tile ∈ {1, 13, 64, n}
/// × threads ∈ {2, 4, 7} × T ∈ {1, 16}: every output element is the same
/// fixed-shape sum (ascending tile fold, samples-ascending within a
/// tile) no matter how many workers computed the tile partials.
#[test]
fn prop_threaded_compress_bit_identical_to_serial() {
    run_prop(
        "threaded-compress-vs-serial",
        PropConfig { cases: 12, ..Default::default() },
        |rng| {
            let n = 20 + rng.below(100) as usize;
            let k = 2 + rng.below(4) as usize;
            let m = 1 + rng.below(24) as usize;
            let t = if rng.below(2) == 0 { 1 } else { 16 };
            let mut c = Matrix::randn(n, k, rng);
            for i in 0..n {
                c[(i, 0)] = 1.0;
            }
            let x = Matrix::randn(n, m, rng);
            let ys = Matrix::randn(n, t, rng);
            (ys, c, x)
        },
        |(ys, c, x)| {
            let (n, m) = (ys.rows, x.cols);
            for tile in [1usize, 13, 64, n] {
                let serial =
                    compress_variant_block_opts(ys, c, x, 0, m, 5, Some(tile), Some(1));
                let (yty_s, cty_s) = compress_yside(ys, c, Some(tile), Some(1));
                for threads in [2usize, 4, 7] {
                    let par = compress_variant_block_opts(
                        ys,
                        c,
                        x,
                        0,
                        m,
                        5,
                        Some(tile),
                        Some(threads),
                    );
                    let tag = format!("tile={tile} threads={threads}");
                    for (name, got, want) in [
                        ("xty", &par.xty.data, &serial.xty.data),
                        ("xtx", &par.xtx, &serial.xtx),
                        ("ctx", &par.ctx.data, &serial.ctx.data),
                    ] {
                        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                            if g.to_bits() != w.to_bits() {
                                return Err(format!("{tag} {name}[{i}]: {g} vs {w}"));
                            }
                        }
                    }
                    let (yty_p, cty_p) = compress_yside(ys, c, Some(tile), Some(threads));
                    let got = yty_p.iter().chain(cty_p.data.iter());
                    let want = yty_s.iter().chain(cty_s.data.iter());
                    for (i, (g, w)) in got.zip(want).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!("{tag} yside[{i}]: {g} vs {w}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

const FRAC: u32 = 24;
const MAG: u32 = 5;

fn random_cp(rng: &mut Rng, n: usize, k: usize, m: usize, t: usize) -> CompressedParty {
    CompressedParty {
        n,
        yty: fixed_repr_vec(rng, t, FRAC, MAG),
        cty: Matrix::from_vec(k, t, fixed_repr_vec(rng, k * t, FRAC, MAG)),
        ctc: Matrix::from_vec(k, k, fixed_repr_vec(rng, k * k, FRAC, MAG)),
        // R never enters the secure sum
        r: Matrix::zeros(k, k),
        xty: Matrix::from_vec(m, t, fixed_repr_vec(rng, m * t, FRAC, MAG)),
        xtx: fixed_repr_vec(rng, m, FRAC, MAG),
        ctx: Matrix::from_vec(k, m, fixed_repr_vec(rng, k * m, FRAC, MAG)),
    }
}

/// flatten → backend secure sum → unflatten reproduces the exact
/// elementwise aggregate bit-for-bit, for random (P, K, M, T): the wire
/// encoding is lossless on fixed-point-representable inputs on every
/// backend.
#[test]
fn prop_flatten_secure_sum_unflatten_identity() {
    run_prop(
        "flatten-secure-sum-unflatten",
        PropConfig { cases: 32, ..Default::default() },
        |rng| {
            let parties = 2 + rng.below(3) as usize;
            let k = 1 + rng.below(4) as usize;
            let m = 1 + rng.below(16) as usize;
            let t = 1 + rng.below(4) as usize;
            let cps: Vec<CompressedParty> = (0..parties)
                .map(|_| {
                    let n = 10 + rng.below(90) as usize;
                    random_cp(rng, n, k, m, t)
                })
                .collect();
            let mask_seed = rng.next_u64();
            (cps, mask_seed)
        },
        |(cps, mask_seed)| {
            let codec = FixedCodec::new(FRAC);
            let parties = cps.len();
            let (layout, _) = flatten_for_sum(&cps[0]);
            let flats: Vec<Vec<f64>> =
                cps.iter().map(|cp| flatten_for_sum(cp).1).collect();
            // exact elementwise aggregate (all values on the 2^-24 grid,
            // so the f64 sums are exact)
            let mut exact = vec![0.0f64; layout.len()];
            for f in &flats {
                for (a, b) in exact.iter_mut().zip(f) {
                    *a += b;
                }
            }
            let expect = unflatten_sum(layout, &exact)
                .map_err(|e| format!("unflatten exact: {e:#}"))?;

            // masked: real pairwise masks must cancel exactly
            let mut rng = Rng::new(*mask_seed);
            let seeds = PairwiseMasker::session_seeds(parties, &mut rng);
            let contributions: Vec<Vec<u64>> = flats
                .iter()
                .enumerate()
                .map(|(p, f)| {
                    let mut enc = codec.encode_vec(f).map_err(|e| format!("{e:#}"))?;
                    PairwiseMasker::new(p, parties, seeds[p].clone())
                        .mask_in_place(&mut enc);
                    Ok(enc)
                })
                .collect::<Result<_, String>>()?;
            let masked = codec.decode_vec(&aggregate_masked(&contributions));

            // Shamir: share, route, share-wise sum, reconstruct
            let threshold = 2.min(parties);
            let mut routed: Vec<Vec<Vec<Fe>>> = vec![Vec::new(); parties];
            for f in &flats {
                let secrets: Vec<Fe> = f
                    .iter()
                    .map(|&v| {
                        Ok(Fe::from_i64(
                            codec.encode(v).map_err(|e| format!("{e:#}"))? as i64,
                        ))
                    })
                    .collect::<Result<_, String>>()?;
                let shares = shamir::share_vec(&secrets, parties, threshold, &mut rng);
                for (q, sv) in shares.into_iter().enumerate() {
                    routed[q].push(sv.into_iter().map(|s| s.y).collect());
                }
            }
            let sums: Vec<Vec<Fe>> = routed
                .iter()
                .map(|incoming| {
                    let mut acc = vec![Fe(0); layout.len()];
                    for sv in incoming {
                        for (a, &s) in acc.iter_mut().zip(sv) {
                            *a = a.add(s);
                        }
                    }
                    acc
                })
                .collect();
            let shamir_sum: Vec<f64> = (0..layout.len())
                .map(|i| {
                    let shares: Vec<shamir::Share> = (0..threshold)
                        .map(|q| shamir::Share { x: q as u64 + 1, y: sums[q][i] })
                        .collect();
                    shamir::reconstruct(&shares).to_i64() as f64 / codec.scale()
                })
                .collect();

            for (name, summed) in
                [("plaintext", &exact), ("masked", &masked), ("shamir", &shamir_sum)]
            {
                let agg = unflatten_sum(layout, summed)
                    .map_err(|e| format!("unflatten {name}: {e:#}"))?;
                if agg.n != expect.n {
                    return Err(format!("{name}: n {} vs {}", agg.n, expect.n));
                }
                for (what, got, want) in [
                    ("yty", &agg.yty, &expect.yty),
                    ("xtx", &agg.xtx, &expect.xtx),
                    ("cty", &agg.cty.data, &expect.cty.data),
                    ("ctc", &agg.ctc.data, &expect.ctc.data),
                    ("xty", &agg.xty.data, &expect.xty.data),
                    ("ctx", &agg.ctx.data, &expect.ctx.data),
                ] {
                    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!(
                                "{name} {what}[{i}]: {g} vs exact {w} (not lossless)"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
