//! Shared conformance/test harness for the integration suites.
//!
//! One scenario definition ([`Scenario`]) runs across the full matrix
//! {plaintext, masked, Shamir} × {in-proc, TCP} × {Rust, artifact} via
//! [`run_conformance`], asserting bit-identical scan + SELECT statistics
//! against the Rust/in-proc baseline of each backend — the contract the
//! artifact kernel suite (reference executor) is pinned to. The
//! per-backend loops previously copy-pasted across
//! `integration_shard.rs` / `integration_multitrait.rs` /
//! `integration_select.rs` live here instead ([`backends`], [`spec_for`],
//! [`cfg`], [`run`], [`assert_bits_eq`]).
//!
//! Each integration test crate pulls this in with `mod common;`, so any
//! single crate only uses a subset of the helpers.

#![allow(dead_code)]

use dash::coordinator::{
    run_multi_party_scan_t, run_session_batch, BatchOptions, MultiPartyScanResult,
    SessionBatchResult, SessionRun, SessionSpec, Transport,
};
use dash::gwas::{generate_cohort, Cohort, CohortSpec};
use dash::mpc::Backend;
use dash::runtime::ArtifactExec;
use dash::scan::{Glm, ScanConfig, ScanOutput, SelectOutput, SelectPolicy, ShardPlan};

/// The three MPC backends of the conformance matrix.
pub fn backends() -> [Backend; 3] {
    [Backend::Plaintext, Backend::Masked, Backend::Shamir { threshold: 2 }]
}

/// Which compute engine the parties run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// pure-Rust streaming kernels
    Rust,
    /// artifact kernel suite, reference executor (bit-identical contract)
    Artifact,
}

impl Compute {
    pub fn all() -> [Compute; 2] {
        [Compute::Rust, Compute::Artifact]
    }
}

/// Standard synthetic cohort used by the integration suites.
pub fn spec_for(parties: usize, n_per: usize, m: usize, t: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_per; parties],
        m_variants: m,
        n_traits: t,
        n_causal: 3.min(m),
        effect_sd: 0.4,
        fst: 0.05,
        party_admixture: (0..parties)
            .map(|i| if parties == 1 { 0.5 } else { i as f64 / (parties - 1) as f64 })
            .collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

/// Standard scan config of the integration suites (Rust compute path).
pub fn cfg(backend: Backend, shard_m: usize) -> ScanConfig {
    ScanConfig { backend, shard_m, block_m: 32, threads: Some(2), ..Default::default() }
}

/// Scan config for a conformance-matrix cell.
pub fn cfg_compute(backend: Backend, shard_m: usize, compute: Compute) -> ScanConfig {
    let mut c = cfg(backend, shard_m);
    if compute == Compute::Artifact {
        c.use_artifacts = true;
        // pin the executor: conformance is a bit-level contract, which
        // only the reference executor guarantees
        c.artifact_exec = ArtifactExec::Reference;
    }
    c
}

/// Run one session (panics on protocol errors — conformance scenarios
/// are all well-formed).
pub fn run(
    cohort: &Cohort,
    cfg: &ScanConfig,
    transport: Transport,
    seed: u64,
) -> MultiPartyScanResult {
    run_multi_party_scan_t(cohort, cfg, transport, seed).unwrap()
}

/// In-proc session with the standard config.
pub fn run_inproc(
    cohort: &Cohort,
    backend: Backend,
    shard_m: usize,
    seed: u64,
) -> MultiPartyScanResult {
    run(cohort, &cfg(backend, shard_m), Transport::InProc, seed)
}

/// Bit-level equality, NaN-safe (identical computations must produce
/// identical bit patterns, including NaN payloads for collinear
/// variants).
pub fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for j in 0..a.len() {
        assert_eq!(a[j].to_bits(), b[j].to_bits(), "{what}[{j}]: {} vs {}", a[j], b[j]);
    }
}

/// All scan statistics of two sessions bit-identical (every trait's
/// β/σ̂/p plus the covariate fit).
pub fn assert_scan_bits_eq(a: &MultiPartyScanResult, b: &MultiPartyScanResult, label: &str) {
    assert_output_bits_eq(&a.output, &b.output, label);
}

/// Output-level variant of [`assert_scan_bits_eq`] (usable for
/// multiplexed [`SessionRun`]s too).
pub fn assert_output_bits_eq(a: &ScanOutput, b: &ScanOutput, label: &str) {
    assert_eq!(a.t(), b.t(), "{label}: trait count");
    for tt in 0..a.t() {
        assert_bits_eq(
            &a.assoc[tt].beta,
            &b.assoc[tt].beta,
            &format!("{label} trait {tt} beta"),
        );
        assert_bits_eq(
            &a.assoc[tt].se,
            &b.assoc[tt].se,
            &format!("{label} trait {tt} se"),
        );
        assert_bits_eq(
            &a.assoc[tt].p,
            &b.assoc[tt].p,
            &format!("{label} trait {tt} p"),
        );
    }
    for (i, (fa, fb)) in a.covariate_fit.iter().zip(&b.covariate_fit).enumerate() {
        assert_bits_eq(&fa.gamma, &fb.gamma, &format!("{label} fit {i} gamma"));
    }
}

/// SELECT outputs of two sessions identical: same shortlist, same lanes,
/// and bit-identical statistics for every pick of every round.
pub fn assert_select_bits_eq(
    a: &MultiPartyScanResult,
    b: &MultiPartyScanResult,
    label: &str,
) {
    assert_select_out_eq(&a.select, &b.select, label);
}

/// Output-level variant of [`assert_select_bits_eq`].
pub fn assert_select_out_eq(
    a: &Option<SelectOutput>,
    b: &Option<SelectOutput>,
    label: &str,
) {
    match (a, b) {
        (None, None) => {}
        (Some(sa), Some(sb)) => {
            assert_eq!(sa.candidates, sb.candidates, "{label}: candidates");
            assert_eq!(sa.lanes(), sb.lanes(), "{label}: lanes");
            assert_eq!(sa.rounds.len(), sb.rounds.len(), "{label}: rounds");
            for (ra, rb) in sa.rounds.iter().zip(&sb.rounds) {
                assert_eq!(ra.round, rb.round);
                assert_eq!(ra.picks.len(), rb.picks.len());
                for (lane, (pa, pb)) in ra.picks.iter().zip(&rb.picks).enumerate() {
                    match (pa, pb) {
                        (None, None) => {}
                        (Some(pa), Some(pb)) => {
                            let what = format!("{label} round {} lane {lane}", ra.round);
                            assert_eq!(pa.variant, pb.variant, "{what}: variant");
                            assert_eq!(pa.trait_idx, pb.trait_idx, "{what}: trait");
                            assert_eq!(pa.beta.to_bits(), pb.beta.to_bits(), "{what}: beta");
                            assert_eq!(pa.se.to_bits(), pb.se.to_bits(), "{what}: se");
                            assert_eq!(pa.p.to_bits(), pb.p.to_bits(), "{what}: p");
                        }
                        other => panic!("{label}: pick divergence {other:?}"),
                    }
                }
            }
        }
        other => panic!("{label}: SELECT presence divergence ({:?})", other.0.is_some()),
    }
}

/// A multiplexed session run bit-identical to a serial baseline.
pub fn assert_run_matches(run: &SessionRun, baseline: &MultiPartyScanResult, label: &str) {
    assert_output_bits_eq(&run.output, &baseline.output, label);
    assert_select_out_eq(&run.select, &baseline.select, label);
}

/// Run `sessions` identical multiplexed sessions over shared per-party
/// connections and return the batch (panicking on wiring errors;
/// per-session results stay `Result`s).
pub fn run_batch(
    cohort: &Cohort,
    cfg: &ScanConfig,
    sessions: usize,
    max_concurrent: usize,
    transport: Transport,
    seed: u64,
) -> SessionBatchResult {
    let specs: Vec<SessionSpec> =
        (0..sessions).map(|_| SessionSpec { cfg: cfg.clone(), seed }).collect();
    run_session_batch(
        cohort,
        &specs,
        &BatchOptions { transport, max_concurrent, ..Default::default() },
    )
    .unwrap()
}

/// One conformance scenario: a cohort shape plus protocol knobs, run
/// identically across every cell of the backend × transport × compute
/// matrix.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub parties: usize,
    pub n_per: usize,
    pub m: usize,
    pub t: usize,
    pub shard_m: usize,
    /// worker-thread budget for the tiled compress kernels (0 = leave
    /// the config default, i.e. the harness `threads` knob); any value
    /// must be bit-identical to the serial baseline
    pub compress_threads: usize,
    pub select_k: usize,
    pub select_alpha: f64,
    pub select_candidates: usize,
    pub select_policy: SelectPolicy,
    /// which GLM the scenario fits; [`Glm::Logistic`] thresholds the
    /// cohort traits into 0/1 labels and runs the secure IRLS protocol
    pub glm: Glm,
    pub cohort_seed: u64,
    pub session_seed: u64,
    /// also run the TCP transport cells (slower; off by default)
    pub tcp: bool,
    /// also run the epoll-reactor transport cells (one readiness thread
    /// driving every connection; linux-only — silently skipped
    /// elsewhere)
    pub reactor: bool,
    /// additionally run this many *concurrent multiplexed* sessions per
    /// cell, each of which must be bit-identical to the cell's serial
    /// baseline (1 = skip the multiplexed pass)
    pub sessions: usize,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "scenario",
            parties: 3,
            n_per: 40,
            m: 70,
            t: 1,
            shard_m: 0,
            compress_threads: 0,
            select_k: 0,
            select_alpha: 0.5,
            select_candidates: 8,
            select_policy: SelectPolicy::Union,
            glm: Glm::Linear,
            cohort_seed: 0xC0DE,
            session_seed: 0x5EED,
            tcp: false,
            reactor: false,
            sessions: 1,
        }
    }
}

impl Scenario {
    fn config(&self, backend: Backend, compute: Compute) -> ScanConfig {
        let mut c = cfg_compute(backend, self.shard_m, compute);
        if self.compress_threads > 0 {
            c.compress_threads = Some(self.compress_threads);
        }
        c.select_k = self.select_k;
        c.select_alpha = self.select_alpha;
        c.select_candidates = self.select_candidates;
        c.select_policy = self.select_policy;
        c.glm = self.glm;
        c
    }

    /// The scenario's cohort spec (0/1 traits for logistic scenarios).
    pub fn spec(&self) -> CohortSpec {
        let mut spec = spec_for(self.parties, self.n_per, self.m, self.t);
        spec.binary_traits = self.glm == Glm::Logistic;
        spec
    }

    /// Number of shards this scenario's plan streams over.
    pub fn shards(&self) -> usize {
        ShardPlan::new(self.m, self.shard_m).count()
    }
}

/// Run one scenario across the full conformance matrix. For each
/// backend, the Rust/in-proc session is the baseline; every other cell
/// (artifact compute, TCP transport, and their combination) must
/// reproduce its scan + SELECT statistics bit-for-bit. Artifact cells
/// additionally assert the suite's pass accounting: exactly one
/// trait-batched Y-side pass and one X-side pass per shard **regardless
/// of T**. Returns the per-(backend, compute) in-proc results for extra
/// scenario-specific assertions.
pub fn run_conformance(sc: &Scenario) -> Vec<(Backend, Compute, MultiPartyScanResult)> {
    let cohort = generate_cohort(&sc.spec(), sc.cohort_seed);
    let mut out = Vec::new();
    for backend in backends() {
        let baseline = run(
            &cohort,
            &sc.config(backend, Compute::Rust),
            Transport::InProc,
            sc.session_seed,
        );
        assert_eq!(baseline.metrics.shards, sc.shards(), "{}: shard plan", sc.name);
        let mut transports = vec![Transport::InProc];
        if sc.tcp {
            transports.push(Transport::Tcp);
        }
        if sc.reactor && cfg!(target_os = "linux") {
            transports.push(Transport::Reactor);
        }
        // lowered-entry count of a single artifact session, captured
        // from the artifact × in-proc cell below (the shared-engine
        // reference point for the multiplexed pass)
        let mut single_lowered = None;
        for compute in Compute::all() {
            for &transport in &transports {
                if compute == Compute::Rust && transport == Transport::InProc {
                    continue; // that's the baseline itself
                }
                let label = format!(
                    "{} [{backend:?} × {transport:?} × {compute:?}]",
                    sc.name
                );
                let res =
                    run(&cohort, &sc.config(backend, compute), transport, sc.session_seed);
                assert_scan_bits_eq(&res, &baseline, &label);
                assert_select_bits_eq(&res, &baseline, &label);
                if compute == Compute::Artifact {
                    for (p, km) in res.party_kernels.iter().enumerate() {
                        assert_eq!(
                            km.yside_passes(),
                            1,
                            "{label}: party {p} Y-side passes"
                        );
                        if sc.glm == Glm::Logistic {
                            // IRLS replaces the linear shard rounds: one
                            // reweighted base pass per Newton step plus a
                            // single weighted shard sweep at the final β.
                            assert_eq!(
                                km.xside_passes(),
                                0,
                                "{label}: party {p} linear X-side passes"
                            );
                            assert_eq!(
                                km.irls_base_passes(),
                                res.metrics.irls_iters as u64,
                                "{label}: party {p} IRLS base passes — one \
                                 per Newton iteration"
                            );
                            assert_eq!(
                                km.irls_shard_passes(),
                                sc.shards() as u64,
                                "{label}: party {p} IRLS shard passes"
                            );
                        } else {
                            assert_eq!(
                                km.xside_passes(),
                                sc.shards() as u64,
                                "{label}: party {p} X-side passes — one per \
                                 shard, independent of T={}",
                                sc.t
                            );
                        }
                    }
                    if transport == Transport::InProc {
                        single_lowered = Some(res.party_kernels[0].lowered_entries());
                    }
                }
                if transport == Transport::InProc {
                    out.push((backend, compute, res));
                }
            }
        }
        // Multiplexed pass: `sessions` concurrent sessions over one
        // shared connection pair per party, every cell of the same
        // matrix, every session bit-identical to this backend's serial
        // baseline — with one shared artifact engine per party (no
        // per-session recompiles).
        if sc.sessions > 1 {
            let single_lowered =
                single_lowered.expect("artifact × in-proc cell ran before the session pass");
            for compute in Compute::all() {
                for &transport in &transports {
                    let label = format!(
                        "{} [{backend:?} × {transport:?} × {compute:?} × {} sessions]",
                        sc.name, sc.sessions
                    );
                    let batch = run_batch(
                        &cohort,
                        &sc.config(backend, compute),
                        sc.sessions,
                        sc.sessions,
                        transport,
                        sc.session_seed,
                    );
                    assert_eq!(batch.failed, 0, "{label}: party-side failures");
                    assert_eq!(batch.residual_sessions, 0, "{label}: leaked sessions");
                    assert_eq!(batch.runs.len(), sc.sessions, "{label}: run count");
                    for (i, run) in batch.runs.iter().enumerate() {
                        let run = run
                            .as_ref()
                            .unwrap_or_else(|e| panic!("{label}: session {i}: {e:#}"));
                        assert_run_matches(run, &baseline, &format!("{label} #{i}"));
                    }
                    if compute == Compute::Artifact {
                        for (p, km) in batch.party_kernels.iter().enumerate() {
                            assert_eq!(
                                km.lowered_entries(),
                                single_lowered,
                                "{label}: party {p} lowered entries — the engine \
                                 (and its lowering cache) must be shared across \
                                 sessions, not rebuilt per session"
                            );
                            if sc.glm == Glm::Logistic {
                                assert_eq!(
                                    km.irls_shard_passes(),
                                    (sc.sessions * sc.shards()) as u64,
                                    "{label}: party {p} IRLS shard passes"
                                );
                            } else {
                                assert_eq!(
                                    km.xside_passes(),
                                    (sc.sessions * sc.shards()) as u64,
                                    "{label}: party {p} X-side passes"
                                );
                            }
                        }
                    }
                }
            }
        }
        out.push((backend, Compute::Rust, baseline));
    }
    out
}

/// Declare `#[test]` functions from scenario literals:
///
/// ```ignore
/// mod common;
/// conformance_scenarios! {
///     scan_sharded_t16: { shard_m: 16, t: 16 },
/// }
/// ```
#[macro_export]
macro_rules! conformance_scenarios {
    ($($name:ident: { $($field:ident: $value:expr),* $(,)? }),* $(,)?) => {
        $(
            #[test]
            fn $name() {
                let scenario = $crate::common::Scenario {
                    name: stringify!($name),
                    $($field: $value,)*
                    ..Default::default()
                };
                $crate::common::run_conformance(&scenario);
            }
        )*
    };
}
