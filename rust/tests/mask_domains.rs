//! Session-keyed mask/share domain separation (regression tests for the
//! concurrent-session service): two sessions configured with *identical*
//! pairwise seeds must draw disjoint randomness streams on both secure
//! backends, keyed only by their session ids — otherwise multiplexed
//! sessions would reuse one-time masks (masked backend) or sharing
//! polynomials (Shamir), breaking the security argument of DESIGN.md
//! §Sessions.

use dash::mpc::field::Fe;
use dash::mpc::masking::{aggregate_masked, PairwiseMasker};
use dash::mpc::shamir;

const SEEDS: [u64; 3] = [0xAA11, 0xBB22, 0xCC33];

/// Fraction of equal words two supposedly-independent u64 streams may
/// share before we call it overlap (256 words: expected ≈ 0 collisions).
fn assert_disjoint(a: &[u64], b: &[u64], what: &str) {
    assert_eq!(a.len(), b.len());
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    assert!(same <= 1, "{what}: {same}/{} words equal", a.len());
}

/// The mask stream of (seed, session, round): mask a zero vector.
fn mask_stream(session: u64, round_skip: u64) -> Vec<u64> {
    let mut m = PairwiseMasker::with_domain(0, 3, SEEDS.to_vec(), session);
    let mut v = vec![0u64; 256];
    for _ in 0..round_skip {
        let mut skip = vec![0u64; 1];
        m.mask_in_place(&mut skip);
    }
    m.mask_in_place(&mut v);
    v
}

#[test]
fn identical_seeds_different_sessions_give_disjoint_mask_streams() {
    // every (session, round) pair draws a fresh stream
    let s1r0 = mask_stream(1, 0);
    let s2r0 = mask_stream(2, 0);
    let s1r1 = mask_stream(1, 1);
    let s2r1 = mask_stream(2, 1);
    assert_disjoint(&s1r0, &s2r0, "sessions at round 0");
    assert_disjoint(&s1r1, &s2r1, "sessions at round 1");
    assert_disjoint(&s1r0, &s1r1, "rounds within session 1");
    assert_disjoint(&s1r0, &s2r1, "cross session × round");
    // determinism: the same (session, round) reproduces exactly
    assert_eq!(s1r0, mask_stream(1, 0));
}

#[test]
fn masks_still_cancel_within_each_session_domain() {
    for session in [1u64, 2, 77] {
        let mut maskers: Vec<PairwiseMasker> = (0..3)
            .map(|p| {
                // symmetric seed matrix rows for a 3-party ring built
                // from the shared unordered-pair seeds
                let row = match p {
                    0 => vec![0, SEEDS[0], SEEDS[1]],
                    1 => vec![SEEDS[0], 0, SEEDS[2]],
                    _ => vec![SEEDS[1], SEEDS[2], 0],
                };
                PairwiseMasker::with_domain(p, 3, row, session)
            })
            .collect();
        let plain: Vec<Vec<u64>> = (0..3).map(|p| vec![(p + 1) as u64; 64]).collect();
        let mut masked = plain.clone();
        for (p, v) in masked.iter_mut().enumerate() {
            maskers[p].mask_in_place(v);
            assert_ne!(v, &plain[p], "session {session}: mask must change the vector");
        }
        assert_eq!(aggregate_masked(&masked), vec![6u64; 64]);
    }
}

#[test]
fn shamir_session_rngs_are_disjoint_and_deterministic() {
    let mut a1 = shamir::session_rng(&SEEDS, 0, 1);
    let mut a2 = shamir::session_rng(&SEEDS, 0, 2);
    let s1: Vec<u64> = (0..256).map(|_| a1.next_u64()).collect();
    let s2: Vec<u64> = (0..256).map(|_| a2.next_u64()).collect();
    assert_disjoint(&s1, &s2, "shamir share randomness across sessions");
    // distinct parties stay separated too
    let mut b1 = shamir::session_rng(&SEEDS, 1, 1);
    let sb: Vec<u64> = (0..256).map(|_| b1.next_u64()).collect();
    assert_disjoint(&s1, &sb, "shamir share randomness across parties");
    // deterministic per (seeds, party, session)
    let mut again = shamir::session_rng(&SEEDS, 0, 1);
    assert_eq!(s1[0], again.next_u64());
}

#[test]
fn shamir_share_streams_differ_across_sessions_but_reconstruct_identically() {
    let secrets: Vec<Fe> = (0..32i64).map(|i| Fe::from_i64(i * 7 - 50)).collect();
    let share_y = |session: u64| -> Vec<Vec<u64>> {
        let mut rng = shamir::session_rng(&SEEDS, 0, session);
        shamir::share_vec(&secrets, 3, 2, &mut rng)
            .iter()
            .map(|sv| sv.iter().map(|s| s.y.0).collect())
            .collect()
    };
    let y1 = share_y(1);
    let y2 = share_y(2);
    for (p, (a, b)) in y1.iter().zip(&y2).enumerate() {
        assert_disjoint(a, b, &format!("party-{p} share vector across sessions"));
    }
    // both sessions' shares reconstruct the same secrets (any quorum);
    // layout is shares[party][secret]
    for session in [1u64, 2] {
        let mut rng = shamir::session_rng(&SEEDS, 0, session);
        let shares = shamir::share_vec(&secrets, 3, 2, &mut rng);
        for (i, want) in secrets.iter().enumerate() {
            let quorum = [shares[0][i], shares[2][i]];
            assert_eq!(shamir::reconstruct(&quorum).0, want.0, "session {session} [{i}]");
        }
    }
}
