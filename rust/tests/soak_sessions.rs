//! Soak/regression: 32 sequential sessions through one SessionManager
//! over one set of shared connections and one shared artifact engine per
//! party. Per-session state must actually be freed — no monotonic growth
//! in peak resident kernel-block bytes, no lowering-cache growth beyond
//! the shapes of a single session, no leaked demux queues — and cost
//! must scale exactly linearly in sessions (pass counts), with every
//! session bit-identical to the first.

mod common;

use common::{assert_output_bits_eq, cfg_compute, spec_for, Compute};
use dash::coordinator::{run_session_batch, BatchOptions, SessionSpec};
use dash::gwas::generate_cohort;
use dash::mpc::Backend;

fn soak(sessions: usize) -> dash::coordinator::SessionBatchResult {
    let cohort = generate_cohort(&spec_for(3, 30, 32, 2), 0x50AC);
    // artifact compute: the kernel meter is the state-growth handle
    let c = cfg_compute(Backend::Masked, 8, Compute::Artifact);
    let specs: Vec<SessionSpec> =
        (0..sessions).map(|_| SessionSpec { cfg: c.clone(), seed: 3 }).collect();
    run_session_batch(
        &cohort,
        &specs,
        &BatchOptions { max_concurrent: 1, ..Default::default() },
    )
    .unwrap()
}

#[test]
fn thirty_two_sequential_sessions_free_their_state() {
    let small = soak(2);
    let big = soak(32);
    assert_eq!(big.runs.len(), 32);
    // each of the 3 party services served all 32 sessions
    assert_eq!(big.served, 32 * 3);
    assert_eq!(big.failed, 0);
    // no leaked leader-side demux queues
    assert_eq!(big.residual_sessions, 0);

    // every session produced the identical (bit-for-bit) result
    let first = big.runs[0].as_ref().unwrap();
    for run in &big.runs[1..] {
        let run = run.as_ref().unwrap();
        assert_output_bits_eq(&run.output, &first.output, "soak session");
        // …at identical per-session wire cost (no per-session drift)
        assert_eq!(run.metrics.bytes_total, first.metrics.bytes_total);
    }

    for (p, (km2, km32)) in
        small.party_kernels.iter().zip(&big.party_kernels).enumerate()
    {
        // lowering cache: the 32-session run lowers exactly the same
        // entries as the 2-session run — shapes, not sessions, bound it
        assert_eq!(
            km32.lowered_entries(),
            km2.lowered_entries(),
            "party {p}: lowering cache grew with session count"
        );
        // peak resident kernel-block bytes: identical, i.e. each
        // session's blocks were freed before the next session ran
        assert_eq!(
            km32.peak_block_bytes(),
            km2.peak_block_bytes(),
            "party {p}: peak resident block bytes grew with session count"
        );
        // pass counts scale exactly linearly (32/2 = 16×): all work was
        // done, none duplicated
        assert_eq!(
            km32.xside_passes(),
            16 * km2.xside_passes(),
            "party {p}: X-side passes"
        );
        assert_eq!(
            km32.yside_passes(),
            16 * km2.yside_passes(),
            "party {p}: Y-side passes"
        );
        // every pass after the first session's lowering hits the cache
        assert_eq!(
            km32.lowered_entries() + km32.cache_hits(),
            km32.xside_passes() + km32.yside_passes() + km32.select_passes(),
            "party {p}: lowering accounting"
        );
    }
}
