//! Integration: the secure logistic (IRLS) workload — acceptance
//! criteria of the logistic tentpole.
//!
//! * Oracle agreement: the secure scan's null-model fit and per-variant
//!   score statistics match a pooled plaintext Newton–Raphson oracle
//!   within the fixed-point envelope, on all three MPC backends.
//! * Execution invariance: shard width and transport are pure execution
//!   knobs — every combination is bit-identical.
//! * Traffic shape: per-iteration IRLS rounds cost `O(K²·T)` bytes,
//!   independent of M.
//! * Guard rails: quasi-separated cohorts are rejected with a typed
//!   error before their weighted sums can outgrow the fixed-point
//!   envelope; SELECT on a logistic scan is rejected up front; NaN
//!   statistics surface as NaN p-values (never p = 0).

mod common;

use common::{assert_scan_bits_eq, backends, cfg, run, spec_for};
use dash::coordinator::{MultiPartyScanResult, Transport};
use dash::gwas::{generate_cohort, Cohort};
use dash::linalg::Matrix;
use dash::mpc::Backend;
use dash::scan::{Glm, ScanConfig};
use dash::stats::{
    logistic_fit_pooled, logistic_score_scan_pooled, t_two_sided_p,
};

fn logistic_cfg(backend: Backend, shard_m: usize) -> ScanConfig {
    let mut c = cfg(backend, shard_m);
    c.glm = Glm::Logistic;
    c
}

/// Binary (0/1-trait) cohort with the standard integration shape.
fn binary_cohort(parties: usize, n_per: usize, m: usize, t: usize, seed: u64) -> Cohort {
    let mut spec = spec_for(parties, n_per, m, t);
    spec.binary_traits = true;
    generate_cohort(&spec, seed)
}

fn run_logistic(cohort: &Cohort, backend: Backend, shard_m: usize) -> MultiPartyScanResult {
    run(cohort, &logistic_cfg(backend, shard_m), Transport::InProc, 91)
}

/// Stack the per-party matrices into pooled `(Y, C, X)` — what a single
/// trusted analyst would compute on (row-major concatenation).
fn pooled(cohort: &Cohort) -> (Matrix, Matrix, Matrix) {
    let n = cohort.n_total();
    let (mut ys, mut c, mut x) = (Vec::new(), Vec::new(), Vec::new());
    for p in &cohort.parties {
        ys.extend_from_slice(&p.ys.data);
        c.extend_from_slice(&p.c.data);
        x.extend_from_slice(&p.x.data);
    }
    (
        Matrix::from_vec(n, cohort.t(), ys),
        Matrix::from_vec(n, cohort.k(), c),
        Matrix::from_vec(n, cohort.m(), x),
    )
}

/// Fixed-point-envelope comparison: relative tolerance against the
/// oracle value, NaN-for-NaN (zero-information variants must agree on
/// *where* the statistics are undefined).
fn assert_close(a: &[f64], b: &[f64], rel: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for j in 0..a.len() {
        if a[j].is_nan() || b[j].is_nan() {
            assert!(
                a[j].is_nan() && b[j].is_nan(),
                "{what}[{j}]: NaN divergence ({} vs {})",
                a[j],
                b[j]
            );
            continue;
        }
        let tol = rel * b[j].abs().max(1.0);
        assert!(
            (a[j] - b[j]).abs() <= tol,
            "{what}[{j}]: {} vs oracle {} (tol {tol})",
            a[j],
            b[j]
        );
    }
}

/// Acceptance: on every backend, β̂ and p of the secure logistic scan
/// match the pooled plaintext Newton–Raphson oracle within the
/// fixed-point envelope — null-model fit (coefficients, deviance) and
/// per-variant score statistics alike, for every trait.
#[test]
fn secure_logistic_matches_pooled_oracle_all_backends() {
    let cohort = binary_cohort(3, 60, 24, 2, 0xB10);
    // the generator really produced a case/control cohort
    for p in &cohort.parties {
        assert!(p.ys.data.iter().all(|&v| v == 0.0 || v == 1.0));
    }
    let (ys, c, x) = pooled(&cohort);
    let dflt = ScanConfig::default();
    for backend in backends() {
        let res = run_logistic(&cohort, backend, 0);
        assert!(res.metrics.irls_iters >= 2, "{backend:?}: IRLS never iterated");
        assert!(res.metrics.irls_iters <= dflt.irls_max_iter, "{backend:?}");
        for tt in 0..cohort.t() {
            let y = ys.col(tt);
            let fit = logistic_fit_pooled(&y, &c, dflt.irls_max_iter, dflt.irls_tol)
                .expect("oracle fit");
            let label = format!("{backend:?} trait {tt}");
            // iteration schedules may differ by at most the one step a
            // quantized deviance can move the stop decision
            assert!(
                (res.metrics.irls_iters as i64 - fit.iters as i64).abs() <= 1,
                "{label}: secure {} vs oracle {} iterations",
                res.metrics.irls_iters,
                fit.iters
            );
            let null = &res.output.covariate_fit[tt];
            assert_close(&null.gamma, &fit.beta, 2e-3, &format!("{label} gamma"));
            assert_close(&[null.tau2], &[fit.deviance], 1e-3, &format!("{label} deviance"));
            let oracle = logistic_score_scan_pooled(&y, &c, &x, &fit);
            let a = &res.output.assoc[tt];
            assert_eq!(a.df, oracle.df, "{label}: score df");
            assert_close(&a.beta, &oracle.beta, 2e-3, &format!("{label} beta"));
            assert_close(&a.t, &oracle.t, 2e-3, &format!("{label} z"));
            assert_close(&a.p, &oracle.p, 2e-3, &format!("{label} p"));
        }
    }
}

/// Shard width is a pure execution knob for the logistic scan too: any
/// width reproduces the whole-M session bit-for-bit (the IRLS loop is
/// width-free; the weighted pass folds row tiles in canonical order
/// regardless of shard boundaries).
#[test]
fn logistic_bit_identical_across_shard_widths() {
    let cohort = binary_cohort(3, 50, 40, 2, 0xB11);
    let baseline = run_logistic(&cohort, Backend::Masked, 0);
    for width in [7usize, 16, 40, 4096] {
        let res = run_logistic(&cohort, Backend::Masked, width);
        assert_eq!(res.metrics.irls_iters, baseline.metrics.irls_iters, "width {width}");
        assert_scan_bits_eq(&res, &baseline, &format!("shard width {width}"));
    }
}

/// Transport closure: TCP and reactor sessions serialize exactly the
/// same IRLS frames as in-proc — identical statistics and identical
/// IRLS byte accounting.
#[test]
fn logistic_bit_identical_across_transports() {
    let cohort = binary_cohort(3, 40, 24, 1, 0xB12);
    let cfg = logistic_cfg(Backend::Masked, 8);
    let inproc = run(&cohort, &cfg, Transport::InProc, 92);
    let mut transports = vec![Transport::Tcp];
    if cfg!(target_os = "linux") {
        transports.push(Transport::Reactor);
    }
    for transport in transports {
        let res = run(&cohort, &cfg, transport, 92);
        assert_scan_bits_eq(&res, &inproc, &format!("{transport:?}"));
        assert_eq!(res.metrics.irls_iters, inproc.metrics.irls_iters, "{transport:?}");
        assert_eq!(res.metrics.bytes_irls, inproc.metrics.bytes_irls, "{transport:?}");
        assert_eq!(
            res.metrics.bytes_max_irls_round,
            inproc.metrics.bytes_max_irls_round,
            "{transport:?}"
        );
    }
}

/// Per-iteration IRLS traffic is `O(K²·T)` — independent of the number
/// of variants (that is the whole point of running the null model on
/// compressed statistics: iteration cost does not scale with M).
#[test]
fn irls_round_bytes_independent_of_m() {
    let small = binary_cohort(3, 50, 24, 2, 0xB13);
    let large = binary_cohort(3, 50, 96, 2, 0xB13);
    let a = run_logistic(&small, Backend::Masked, 0);
    let b = run_logistic(&large, Backend::Masked, 0);
    assert!(a.metrics.bytes_irls > 0);
    assert!(a.metrics.bytes_max_irls_round > 0);
    assert!(a.metrics.bytes_max_irls_round <= a.metrics.bytes_irls);
    assert_eq!(
        a.metrics.bytes_max_irls_round, b.metrics.bytes_max_irls_round,
        "peak IRLS round bytes must not scale with M ({} variants vs {})",
        small.m(),
        large.m()
    );
}

/// Guard rail: a quasi-separated cohort (a covariate perfectly predicts
/// the outcome, so the MLE is at infinity) is *rejected* with a typed
/// error once the iterate escapes the divergence guard — the session
/// must not silently wrap the growing weighted sums through the
/// fixed-point encoder.
#[test]
fn quasi_separated_cohort_rejected_not_wrapped() {
    let mut cohort = binary_cohort(2, 100, 8, 1, 0xB14);
    for p in cohort.parties.iter_mut() {
        for i in 0..p.n() {
            p.ys[(i, 0)] = if p.c[(i, 1)] > 0.0 { 1.0 } else { 0.0 };
        }
    }
    let mut cfg = logistic_cfg(Backend::Masked, 0);
    cfg.irls_max_iter = 500;
    cfg.irls_tol = 1e-12;
    let err = dash::coordinator::run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 93)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("quasi-separation"),
        "unexpected error: {err:#}"
    );
}

/// Logistic scans have no linear assembler, so the SELECT phase is
/// rejected up front instead of failing obscurely mid-session.
#[test]
fn logistic_rejects_select_phase() {
    let cohort = binary_cohort(2, 40, 12, 1, 0xB15);
    let mut cfg = logistic_cfg(Backend::Masked, 0);
    cfg.select_k = 1;
    let err = dash::coordinator::run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 94)
        .unwrap_err();
    assert!(format!("{err:#}").contains("SELECT"), "unexpected error: {err:#}");
}

/// A variant carrying zero effective information gets NaN statistics
/// end to end — NaN p, not the maximally-significant p = 0 the NaN-t
/// bug used to produce. A monomorphic (all-zero) genotype column keeps
/// its three aggregated sums *exactly* zero through every fixed-point
/// backend, so the V_j guard fires deterministically.
#[test]
fn zero_information_variant_gets_nan_p_end_to_end() {
    let mut cohort = binary_cohort(3, 50, 12, 1, 0xB16);
    for p in cohort.parties.iter_mut() {
        for i in 0..p.n() {
            p.x[(i, 0)] = 0.0; // variant 0 is monomorphic
        }
    }
    let res = run_logistic(&cohort, Backend::Masked, 0);
    let a = &res.output.assoc[0];
    assert!(a.beta[0].is_nan(), "beta[0]={}", a.beta[0]);
    assert!(a.p[0].is_nan(), "p[0]={}", a.p[0]);
    // the rest of the scan is unaffected
    assert!(a.p[1..].iter().filter(|p| p.is_finite()).count() >= 8);
}

/// Regression for the NaN p-value bugfix riding along with this
/// workload: a NaN t statistic must yield a NaN p-value (it previously
/// fell through to p = 0.0 and ranked *first* in SELECT).
#[test]
fn nan_t_statistic_yields_nan_p() {
    assert!(t_two_sided_p(f64::NAN, 10.0).is_nan());
    assert!(t_two_sided_p(f64::NAN, 1e6).is_nan());
    // the finite contract is untouched
    assert_eq!(t_two_sided_p(f64::INFINITY, 10.0), 0.0);
    assert!((t_two_sided_p(0.0, 10.0) - 1.0).abs() < 1e-12);
}
