//! Multiplexed session-service integration: the acceptance criteria of
//! the concurrent-session tentpole.
//!
//! - 16 concurrent sessions over **one shared TCP connection pair per
//!   party** complete with per-session results bit-identical to serial
//!   dedicated-connection runs, on all three MPC backends.
//! - Distinct per-session seeds/configs multiplex cleanly in one batch.
//! - Per-session byte accounting survives multiplexing: each session's
//!   metered bytes equal its serial run's plus exactly the v2 framing
//!   overhead (12 bytes × its frame count).
//! - Session state is freed: no leaked demux queues after a batch.

mod common;

use common::{assert_run_matches, backends, cfg, run_batch, spec_for};
use dash::coordinator::{
    run_multi_party_scan_t, run_session_batch, BatchOptions, SessionSpec, Transport,
};
use dash::gwas::generate_cohort;
use dash::mpc::Backend;
use dash::net::{transport_driver_threads, FRAME_V2_OVERHEAD};
use std::sync::Mutex;

/// Serializes the tests in this binary: the O(1)-transport-threads
/// assertion reads a process-wide monotonic counter, so no other test
/// may spawn transport threads inside its measurement window.
static DRIVER_GATE: Mutex<()> = Mutex::new(());

fn driver_gate() -> std::sync::MutexGuard<'static, ()> {
    DRIVER_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// The headline acceptance run: 16 concurrent sessions multiplexed over
/// one shared TCP connection pair per party, all three backends, every
/// session bit-identical to its serial dedicated-connection run.
#[test]
fn sixteen_concurrent_sessions_over_shared_tcp_match_serial() {
    let _gate = driver_gate();
    let cohort = generate_cohort(&spec_for(3, 24, 30, 1), 0x5E55_0001);
    for backend in backends() {
        let c = cfg(backend, 8);
        let serial = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 77).unwrap();
        let batch = run_batch(&cohort, &c, 16, 16, Transport::Tcp, 77);
        assert_eq!(batch.runs.len(), 16);
        // served counts session-serves summed over the three parties
        assert_eq!(batch.served, 16 * 3, "{backend:?}: party services");
        assert_eq!(batch.failed, 0, "{backend:?}: party-side failures");
        assert_eq!(batch.residual_sessions, 0, "{backend:?}: leaked sessions");
        for (i, run) in batch.runs.iter().enumerate() {
            let run = run
                .as_ref()
                .unwrap_or_else(|e| panic!("{backend:?} session {i}: {e:#}"));
            assert_run_matches(run, &serial, &format!("{backend:?} session {i}"));
        }
    }
}

/// Concurrency is not required for correctness: the same batch at
/// max_concurrent 1 (fully serialized over the shared connections) and
/// at high concurrency produce identical per-session results.
#[test]
fn concurrency_level_does_not_change_results() {
    let _gate = driver_gate();
    let cohort = generate_cohort(&spec_for(3, 24, 30, 2), 0x5E55_0002);
    let c = cfg(Backend::Masked, 8);
    let serialized = run_batch(&cohort, &c, 6, 1, Transport::InProc, 91);
    let concurrent = run_batch(&cohort, &c, 6, 6, Transport::InProc, 91);
    for (a, b) in serialized.runs.iter().zip(&concurrent.runs) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        common::assert_output_bits_eq(&a.output, &b.output, "c1 vs c6");
        assert_eq!(a.metrics.bytes_total, b.metrics.bytes_total, "per-session bytes");
    }
}

/// Per-session byte accounting under multiplexing: a session's metered
/// bytes are its serial (v1, dedicated-connection) bytes plus exactly
/// the v2 session-framing overhead for each of its frames.
#[test]
fn per_session_bytes_equal_serial_plus_framing_overhead() {
    let _gate = driver_gate();
    let cohort = generate_cohort(&spec_for(3, 24, 30, 1), 0x5E55_0003);
    let c = cfg(Backend::Masked, 8);
    let serial = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 55).unwrap();
    let batch = run_batch(&cohort, &c, 3, 3, Transport::InProc, 55);
    for run in &batch.runs {
        let run = run.as_ref().unwrap();
        // leader-side session meters record each of the session's frames
        // exactly once per connection (sends outbound, receives as
        // routed), matching the serial shared-meter convention
        let frames = run.metrics.messages_total;
        assert_eq!(frames, serial.metrics.messages_total, "frame count");
        assert_eq!(
            run.metrics.bytes_total,
            serial.metrics.bytes_total + frames * FRAME_V2_OVERHEAD,
            "bytes = serial + 12/frame"
        );
    }
    // The shared connections carried exactly all sessions' frames plus
    // the orderly-teardown control frames: one empty v2 frame (24 bytes)
    // in each direction per connection.
    let conn_total: u64 = batch.conn_bytes.iter().sum();
    let per_session: u64 = batch
        .runs
        .iter()
        .map(|r| r.as_ref().unwrap().metrics.bytes_total)
        .sum();
    let ctrl = batch.conn_bytes.len() as u64 * 2 * 24;
    assert_eq!(conn_total, per_session + ctrl);
}

/// Reactor acceptance: 16 concurrent sessions over the epoll
/// readiness-loop transport are bit-identical to serial, and the whole
/// batch — six shared connections across three parties — is driven by
/// exactly ONE transport thread (the threaded path spawns one blocking
/// pump per mux, i.e. 2 per party).
#[test]
fn sixteen_concurrent_sessions_over_reactor_match_serial() {
    let _gate = driver_gate();
    if !cfg!(target_os = "linux") {
        eprintln!("skipping: reactor transport is linux-only");
        return;
    }
    let cohort = generate_cohort(&spec_for(3, 24, 30, 1), 0x5E55_0005);
    let c = cfg(Backend::Masked, 8);
    let serial = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 77).unwrap();
    let before = transport_driver_threads();
    let batch = run_batch(&cohort, &c, 16, 16, Transport::Reactor, 77);
    let drivers = transport_driver_threads() - before;
    assert_eq!(drivers, 1, "reactor batch must use exactly one transport thread");
    assert_eq!(batch.served, 16 * 3);
    assert_eq!(batch.failed, 0);
    assert_eq!(batch.residual_sessions, 0);
    for (i, run) in batch.runs.iter().enumerate() {
        let run = run.as_ref().unwrap_or_else(|e| panic!("reactor session {i}: {e:#}"));
        assert_run_matches(run, &serial, &format!("reactor session {i}"));
    }
}

/// Byte accounting is drive-mode independent: the reactor batch meters
/// exactly the same per-session and per-connection byte totals as the
/// threaded-pump batch over the identical workload, including the
/// teardown control frames.
#[test]
fn reactor_byte_accounting_matches_threaded() {
    let _gate = driver_gate();
    if !cfg!(target_os = "linux") {
        eprintln!("skipping: reactor transport is linux-only");
        return;
    }
    let cohort = generate_cohort(&spec_for(3, 24, 30, 1), 0x5E55_0006);
    let c = cfg(Backend::Masked, 8);
    let threaded = run_batch(&cohort, &c, 4, 4, Transport::Tcp, 63);
    let reactor = run_batch(&cohort, &c, 4, 4, Transport::Reactor, 63);
    for (i, (a, b)) in threaded.runs.iter().zip(&reactor.runs).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        common::assert_output_bits_eq(&a.output, &b.output, "threaded vs reactor");
        assert_eq!(
            a.metrics.bytes_total, b.metrics.bytes_total,
            "session {i}: per-session bytes"
        );
        assert_eq!(
            a.metrics.messages_total, b.metrics.messages_total,
            "session {i}: per-session frames"
        );
    }
    let t_total: u64 = threaded.conn_bytes.iter().sum();
    let r_total: u64 = reactor.conn_bytes.iter().sum();
    assert_eq!(t_total, r_total, "shared-connection byte totals");
}

/// Sessions with different seeds produce *different* (properly seeded)
/// results in one batch, each matching its own serial run.
#[test]
fn distinct_seeds_multiplex_cleanly() {
    let _gate = driver_gate();
    let cohort = generate_cohort(&spec_for(3, 24, 30, 1), 0x5E55_0004);
    let c = cfg(Backend::Shamir { threshold: 2 }, 8);
    let specs: Vec<SessionSpec> =
        (0..4).map(|i| SessionSpec { cfg: c.clone(), seed: 100 + i as u64 }).collect();
    let batch = run_session_batch(
        &cohort,
        &specs,
        &BatchOptions { max_concurrent: 4, ..Default::default() },
    )
    .unwrap();
    for (spec, run) in specs.iter().zip(&batch.runs) {
        let run = run.as_ref().unwrap();
        let serial =
            run_multi_party_scan_t(&cohort, &spec.cfg, Transport::InProc, spec.seed)
                .unwrap();
        assert_run_matches(run, &serial, &format!("seed {}", spec.seed));
    }
}
