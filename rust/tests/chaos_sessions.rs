//! Chaos battery for the multiplexed session service: a fault-injecting
//! transport perturbs exactly one frame (drop / duplicate / reorder /
//! cross-session misroute) on one party's shared connection, and the
//! batch must degrade *surgically*:
//!
//! - the batch always completes — never a hang (bounded by the demux
//!   receive timeout);
//! - every affected session fails with a clean error (protocol
//!   `ErrorMsg`, ordering violation, or timeout — never a panic);
//! - zero contamination: every session that reports success is
//!   bit-identical to its serial dedicated-connection run;
//! - at least the untouched sessions succeed.
//!
//! Every fault runs over both drive modes — the in-proc pump-thread
//! transport and (on linux) the epoll reactor — and must degrade the
//! same way: faults through the readiness loop fail only the targeted
//! session, never the loop.

mod common;

use common::{assert_run_matches, cfg, spec_for};
use dash::coordinator::{
    run_multi_party_scan_t, run_session_batch, BatchOptions, MultiPartyScanResult,
    SessionSpec, Transport,
};
use dash::gwas::{generate_cohort, Cohort};
use dash::mpc::Backend;
use dash::net::chaos::{FaultDir, FaultMode, FaultSpec};
use dash::scan::ScanConfig;
use std::time::Duration;

const SESSIONS: usize = 3;
/// the perturbed session (1-based session ids)
const VICTIM: u64 = 2;

fn chaos_cohort() -> Cohort {
    generate_cohort(&spec_for(3, 24, 24, 1), 0xC4A0)
}

fn chaos_cfg() -> ScanConfig {
    cfg(Backend::Masked, 8) // 3 shards
}

/// The drive modes every fault must degrade identically under: the
/// pump-thread transport everywhere, plus the epoll reactor on linux.
fn chaos_transports() -> Vec<Transport> {
    let mut ts = vec![Transport::InProc];
    if cfg!(target_os = "linux") {
        ts.push(Transport::Reactor);
    }
    ts
}

/// Run a faulted batch over one drive mode and enforce the battery-wide
/// invariants: the batch completes, successes are bit-identical to
/// serial, and the never-targeted session 1 survives. Returns which
/// sessions failed.
fn run_chaos_over(fault: FaultSpec, transport: Transport, label: &str) -> Vec<bool> {
    let cohort = chaos_cohort();
    let c = chaos_cfg();
    let serial: MultiPartyScanResult =
        run_multi_party_scan_t(&cohort, &c, Transport::InProc, 7).unwrap();
    let specs: Vec<SessionSpec> =
        (0..SESSIONS).map(|_| SessionSpec { cfg: c.clone(), seed: 7 }).collect();
    let batch = run_session_batch(
        &cohort,
        &specs,
        &BatchOptions {
            transport,
            max_concurrent: SESSIONS,
            recv_timeout: Some(Duration::from_secs(2)),
            fault: Some(fault),
        },
    )
    .unwrap();
    assert_eq!(batch.runs.len(), SESSIONS, "{label}: batch returned");
    assert_eq!(batch.residual_sessions, 0, "{label}: leaked sessions");
    let mut failed = Vec::with_capacity(SESSIONS);
    for (i, run) in batch.runs.iter().enumerate() {
        match run {
            Ok(r) => {
                // zero contamination: success ⇒ bit-identical to serial
                assert_run_matches(r, &serial, &format!("{label} session {}", i + 1));
                failed.push(false);
            }
            Err(e) => {
                // clean failure: a described error, not a panic/hang
                let msg = format!("{e:#}");
                assert!(!msg.is_empty(), "{label}: empty error");
                failed.push(true);
            }
        }
    }
    // session 1 is never targeted by the specs below — it must survive
    assert!(!failed[0], "{label}: untouched session 1 failed");
    failed
}

/// Run the fault over every drive mode and return one failure pattern
/// per mode; callers assert the same surgical degradation on each.
fn run_chaos(fault: FaultSpec, label: &str) -> Vec<Vec<bool>> {
    chaos_transports()
        .into_iter()
        .map(|t| run_chaos_over(fault, t, &format!("{label} [{t:?}]")))
        .collect()
}

/// A dropped party→leader contribution: the victim session times out (or
/// trips an ordering check) and every other session completes.
#[test]
fn dropped_contribution_fails_only_the_victim() {
    for failed in run_chaos(
        FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Drop,
            session: VICTIM,
            nth: 1, // first shard contribution (0 is the base round)
        },
        "drop",
    ) {
        assert!(failed[(VICTIM - 1) as usize], "victim must fail");
        assert_eq!(failed.iter().filter(|&&f| f).count(), 1, "exactly one failure");
    }
}

/// A duplicated contribution frame trips the shard-ordinal check — a
/// clean protocol error, not a silent double count.
#[test]
fn duplicated_contribution_is_detected() {
    for failed in run_chaos(
        FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Duplicate,
            session: VICTIM,
            nth: 1,
        },
        "duplicate",
    ) {
        assert!(failed[(VICTIM - 1) as usize], "victim must fail");
        assert_eq!(failed.iter().filter(|&&f| f).count(), 1, "exactly one failure");
    }
}

/// Two reordered contribution frames trip the ordering check cleanly.
#[test]
fn reordered_contributions_are_detected() {
    for failed in run_chaos(
        FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Reorder,
            session: VICTIM,
            nth: 1,
        },
        "reorder",
    ) {
        assert!(failed[(VICTIM - 1) as usize], "victim must fail");
    }
}

/// A frame misrouted from one session into another: the victim loses a
/// frame, the misroute target either detects the intruder or finishes
/// untouched — and any session that succeeds is bit-identical to serial
/// (enforced by `run_chaos` for every mode).
#[test]
fn cross_session_misroute_never_contaminates() {
    for failed in run_chaos(
        FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Misroute { to: 3 },
            session: VICTIM,
            nth: 1,
        },
        "misroute",
    ) {
        assert!(failed[(VICTIM - 1) as usize], "victim must fail");
    }
}

/// Misroute to a session id nobody opened: the frame is dropped by the
/// demux (counted, not misdelivered) and only the victim fails.
#[test]
fn misroute_to_unknown_session_is_dropped() {
    for failed in run_chaos(
        FaultSpec {
            party: 0,
            dir: FaultDir::Recv,
            mode: FaultMode::Misroute { to: 999 },
            session: VICTIM,
            nth: 1,
        },
        "misroute-unknown",
    ) {
        assert!(failed[(VICTIM - 1) as usize], "victim must fail");
        assert_eq!(failed.iter().filter(|&&f| f).count(), 1, "exactly one failure");
    }
}

/// A party hangup mid-scan (persistent death, not a one-frame glitch):
/// the victim session fails with the *typed* dropout error — the
/// message names the dropped party — and every other session completes
/// bit-identical. The masked backend cannot recover from any death, so
/// this is the clean-typed-failure leg of the dropout contract.
#[test]
fn party_hangup_mid_scan_fails_typed_and_only_the_victim() {
    for transport in chaos_transports() {
        let label = format!("hangup [{transport:?}]");
        let cohort = chaos_cohort();
        let c = chaos_cfg();
        let serial = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 7).unwrap();
        let specs: Vec<SessionSpec> =
            (0..SESSIONS).map(|_| SessionSpec { cfg: c.clone(), seed: 7 }).collect();
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions {
                transport,
                max_concurrent: SESSIONS,
                recv_timeout: Some(Duration::from_secs(2)),
                fault: Some(FaultSpec {
                    party: 0,
                    dir: FaultDir::Recv,
                    // frame 0 is the base round; from the first shard
                    // contribution on, the party is gone for good
                    mode: FaultMode::Hangup,
                    session: VICTIM,
                    nth: 1,
                }),
            },
        )
        .unwrap();
        assert_eq!(batch.residual_sessions, 0, "{label}: leaked sessions");
        for (i, run) in batch.runs.iter().enumerate() {
            let sid = (i + 1) as u64;
            match run {
                Ok(r) => {
                    assert_ne!(sid, VICTIM, "{label}: victim session succeeded");
                    assert_run_matches(r, &serial, &format!("{label} session {sid}"));
                }
                Err(e) => {
                    assert_eq!(sid, VICTIM, "{label}: non-victim session {sid} failed");
                    let msg = format!("{e:#}");
                    // typed dropout, not a bare timeout: the error names
                    // the dead party
                    assert!(
                        msg.contains("party 0"),
                        "{label}: error does not name the dropped party: {msg}"
                    );
                }
            }
        }
        assert!(batch.runs[(VICTIM - 1) as usize].is_err(), "{label}: victim must fail");
    }
}

/// Leader→party faults: dropping a result-broadcast frame leaves the
/// leader's own result intact (still bit-identical) but the party-side
/// service reports the failed session — and nothing hangs.
#[test]
fn dropped_result_broadcast_is_party_side_failure_only() {
    let cohort = chaos_cohort();
    let c = chaos_cfg();
    let serial = run_multi_party_scan_t(&cohort, &c, Transport::InProc, 7).unwrap();
    let specs: Vec<SessionSpec> =
        (0..SESSIONS).map(|_| SessionSpec { cfg: c.clone(), seed: 7 }).collect();
    for transport in chaos_transports() {
        let batch = run_session_batch(
            &cohort,
            &specs,
            &BatchOptions {
                transport,
                max_concurrent: SESSIONS,
                recv_timeout: Some(Duration::from_secs(2)),
                fault: Some(FaultSpec {
                    party: 1,
                    dir: FaultDir::Send,
                    // SETUP=0, COMPRESS=1, then the leader's next sends
                    // to this party are the result broadcast frames
                    nth: 2,
                    mode: FaultMode::Drop,
                    session: VICTIM,
                }),
            },
        )
        .unwrap();
        for (i, run) in batch.runs.iter().enumerate() {
            let run = run
                .as_ref()
                .unwrap_or_else(|e| panic!("{transport:?} session {}: {e:#}", i + 1));
            assert_run_matches(run, &serial, &format!("{transport:?} session {}", i + 1));
        }
        assert_eq!(batch.failed, 1, "{transport:?}: exactly the victim's serve fails");
        assert_eq!(batch.served, SESSIONS * 3 - 1, "{transport:?}");
    }
}
