//! Control-plane integration: the scan-as-a-service daemon of ISSUE 9.
//!
//! - submit → poll → fetch over real HTTP is bit-identical to a direct
//!   [`run_session_batch`] on all three MPC backends (`result_fp` and
//!   the decoded bit patterns themselves);
//! - a saturated worker pool rejects with 429 + `Retry-After` within a
//!   second — admission control never queues forever;
//! - per-tenant quotas admit other tenants and free up on cancel;
//! - cancelling a wedged mid-scan job frees its mux queues
//!   (`residual_sessions == 0`) and removes its checkpoint directory;
//! - a deliberately panicked session settles as `failed`, leaves no
//!   checkpoint behind, and the daemon keeps serving;
//! - a concurrent submit/cancel/status battery never yields an
//!   unexpected status code and every job settles.

mod common;

use common::{backends, cfg, spec_for};
use dash::config::RunConfig;
use dash::coordinator::daemon::job_checkpoint_dir;
use dash::coordinator::{
    result_fingerprint, run_session_batch, BatchOptions, Daemon, DaemonOptions, SessionSpec,
};
use dash::gwas::generate_cohort;
use dash::mpc::Backend;
use dash::net::http::{http_request, Response};
use dash::util::json::Json;
use std::path::Path;
use std::time::{Duration, Instant};

fn daemon(opts: DaemonOptions) -> (Daemon, String) {
    let d = Daemon::start(opts).unwrap();
    let addr = d.addr().to_string();
    (d, addr)
}

/// A small scan+SELECT run config the daemon can regenerate exactly
/// (the cohort is derived from the spec, so config JSON is the whole
/// job description).
fn run_config(backend: Backend, seed: u64) -> RunConfig {
    RunConfig {
        cohort: spec_for(3, 24, 24, 1),
        scan: {
            let mut c = cfg(backend, 8);
            c.select_k = 2;
            c
        },
        seed,
        ..RunConfig::default()
    }
}

fn job_body(rc: &RunConfig) -> Json {
    let mut b = Json::obj();
    b.set("config", rc.to_json());
    b
}

fn submit(addr: &str, body: &Json) -> Response {
    http_request(addr, "POST", "/jobs", Some(body.to_string().as_bytes())).unwrap()
}

fn submit_ok(addr: &str, body: &Json) -> u64 {
    let r = submit(addr, body);
    assert_eq!(r.status, 201, "submit: {}", String::from_utf8_lossy(&r.body));
    r.json_body().unwrap().get("job").and_then(Json::as_usize).unwrap() as u64
}

fn status_of(addr: &str, id: u64) -> Json {
    let r = http_request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(r.status, 200, "status: {}", String::from_utf8_lossy(&r.body));
    r.json_body().unwrap()
}

fn state_of(v: &Json) -> String {
    v.get("status").and_then(Json::as_str).unwrap_or("?").to_string()
}

/// Poll until the job reaches `want` (or panics after `within`).
fn wait_for(addr: &str, id: u64, want: &str, within: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let v = status_of(addr, id);
        let st = state_of(&v);
        if st == want {
            return v;
        }
        assert!(t0.elapsed() < within, "job {id} stuck at `{st}` waiting for `{want}`");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Poll until the job leaves queued/running.
fn wait_settled(addr: &str, id: u64, within: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let v = status_of(addr, id);
        let st = state_of(&v);
        if st != "queued" && st != "running" {
            return v;
        }
        assert!(t0.elapsed() < within, "job {id} still `{st}` after {:?}", t0.elapsed());
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn tempdir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("dash-daemon-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_str().unwrap().to_string()
}

/// Decode one `*_bits` hex array back into the exact f64s.
fn decode_bits(row: &Json, key: &str) -> Vec<f64> {
    match row.get(key) {
        Some(Json::Arr(xs)) => xs
            .iter()
            .map(|x| f64::from_bits(u64::from_str_radix(x.as_str().unwrap(), 16).unwrap()))
            .collect(),
        other => panic!("missing {key}: {other:?}"),
    }
}

/// The headline parity check: for every backend, submit the job over
/// HTTP and compare the fetched result — fingerprint and decoded bit
/// patterns — against an in-process [`run_session_batch`] oracle.
#[test]
fn daemon_result_is_bit_identical_to_run_session_batch() {
    let (d, addr) = daemon(DaemonOptions::default());
    for backend in backends() {
        // normalize through the JSON round-trip the daemon performs, so
        // the oracle sees the exact config the daemon will parse (e.g.
        // the Shamir threshold is re-derived from the backend name)
        let rc = RunConfig::from_json(&run_config(backend, 0xDA01).to_json()).unwrap();
        let cohort = generate_cohort(&rc.cohort, rc.seed);
        let specs = vec![SessionSpec { cfg: rc.scan.clone(), seed: rc.seed }];
        let opts = BatchOptions {
            transport: rc.transport,
            max_concurrent: 1,
            ..Default::default()
        };
        let batch = run_session_batch(&cohort, &specs, &opts).unwrap();
        let oracle = batch.runs.into_iter().next().unwrap().unwrap();
        let want_fp =
            format!("{:016x}", result_fingerprint(&oracle.output, oracle.select.as_ref()));

        let id = submit_ok(&addr, &job_body(&rc));
        let v = wait_settled(&addr, id, Duration::from_secs(120));
        assert_eq!(state_of(&v), "done", "{backend:?}: {}", v.to_string());
        let r = http_request(&addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
        assert_eq!(r.status, 200, "{backend:?}");
        let res = r.json_body().unwrap();
        assert_eq!(
            res.get("result_fp").and_then(Json::as_str),
            Some(want_fp.as_str()),
            "{backend:?}: fingerprint parity"
        );
        let assoc = match res.get("assoc") {
            Some(Json::Arr(a)) => a,
            other => panic!("{backend:?}: missing assoc: {other:?}"),
        };
        assert_eq!(assoc.len(), oracle.output.assoc.len(), "{backend:?}: trait count");
        for (t, row) in assoc.iter().enumerate() {
            let want = &oracle.output.assoc[t];
            for (key, want_xs) in
                [("beta_bits", &want.beta), ("se_bits", &want.se), ("p_bits", &want.p)]
            {
                let got = decode_bits(row, key);
                assert_eq!(got.len(), want_xs.len(), "{backend:?} t{t} {key} length");
                for (j, g) in got.iter().enumerate() {
                    assert_eq!(g.to_bits(), want_xs[j].to_bits(), "{backend:?} t{t} {key}[{j}]");
                }
            }
        }
        // SELECT choices survive the wire too
        let sel = oracle.select.as_ref().expect("oracle ran SELECT");
        let got_sel = res.get("select").expect("result carries select");
        assert_eq!(
            got_sel.get("lanes").and_then(Json::as_usize),
            Some(sel.lanes()),
            "{backend:?}: lanes"
        );
    }
    d.shutdown();
}

/// Admission control: with the single worker pinned and the one queue
/// slot taken, the next submit is rejected in well under a second with
/// 429 + `Retry-After` — never parked on an unbounded queue.
#[test]
fn saturated_pool_rejects_with_429_and_retry_after_within_a_second() {
    let (d, addr) = daemon(DaemonOptions {
        max_jobs: 1,
        queue_cap: 1,
        max_jobs_per_tenant: 16,
        retry_after_s: 3,
        ..Default::default()
    });
    let mut hold = Json::obj();
    hold.set("hold_ms", 60_000usize).set("tenant", "t-sat");
    let a = submit_ok(&addr, &hold);
    wait_for(&addr, a, "running", Duration::from_secs(10));
    let b = submit_ok(&addr, &hold); // occupies the only queue slot

    let t0 = Instant::now();
    let r = submit(&addr, &hold);
    let waited = t0.elapsed();
    assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
    assert!(waited < Duration::from_secs(1), "rejection took {waited:?}");
    assert_eq!(r.header("retry-after"), Some("3"));
    assert_eq!(r.json_body().unwrap().get("retry_after_s").and_then(Json::as_usize), Some(3));

    // a held (running) job has no result yet
    let r = http_request(&addr, "GET", &format!("/jobs/{a}/result"), None).unwrap();
    assert_eq!(r.status, 409);

    // cancelling the queued job frees the slot immediately
    let rc = http_request(&addr, "DELETE", &format!("/jobs/{b}"), None).unwrap();
    assert_eq!(rc.status, 202);
    let c = submit_ok(&addr, &hold);

    for id in [a, c] {
        let _ = http_request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    }
    d.shutdown();
}

/// Tenant quotas are per tenant: one tenant at quota gets 429 while
/// another is admitted, and cancelling frees the quota.
#[test]
fn per_tenant_quota_rejects_only_that_tenant() {
    let (d, addr) = daemon(DaemonOptions {
        max_jobs: 1,
        queue_cap: 8,
        max_jobs_per_tenant: 2,
        ..Default::default()
    });
    let mut alice = Json::obj();
    alice.set("hold_ms", 60_000usize).set("tenant", "alice");
    let a1 = submit_ok(&addr, &alice);
    let a2 = submit_ok(&addr, &alice);
    let r = submit(&addr, &alice);
    assert_eq!(r.status, 429, "alice at quota");
    assert!(r.header("retry-after").is_some());

    // a different tenant is unaffected by alice's quota
    let mut bob = Json::obj();
    bob.set("hold_ms", 60_000usize).set("tenant", "bob");
    let b1 = submit_ok(&addr, &bob);

    // cancel one of alice's: quota frees once it settles
    let _ = http_request(&addr, "DELETE", &format!("/jobs/{a1}"), None).unwrap();
    wait_settled(&addr, a1, Duration::from_secs(10));
    let a3 = submit_ok(&addr, &alice);

    for id in [a2, b1, a3] {
        let _ = http_request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    }
    d.shutdown();
}

/// Cancel mid-scan: a chaos-stalled job wedges after at least one
/// checkpoint is on disk; `DELETE` wakes it, the batch unwinds with no
/// leaked session queues, and the job's checkpoint directory is gone
/// by the time the status reads `cancelled`.
#[test]
fn cancel_mid_scan_frees_queues_and_removes_checkpoints() {
    let root = tempdir("cancel");
    let (d, addr) = daemon(DaemonOptions { checkpoint_root: root.clone(), ..Default::default() });
    let mut rc = run_config(Backend::Masked, 0xDA04);
    rc.scan.select_k = 0;
    let mut body = job_body(&rc);
    body.set("fault", "stall");
    let id = submit_ok(&addr, &body);
    wait_for(&addr, id, "running", Duration::from_secs(30));

    // the stall drops the third leader-bound frame (shard 1), so the
    // shard-0 checkpoint lands before the job wedges
    let dir = job_checkpoint_dir(&root, id);
    let t0 = Instant::now();
    while !Path::new(&dir).exists() {
        assert!(t0.elapsed() < Duration::from_secs(20), "no checkpoint appeared in {dir}");
        std::thread::sleep(Duration::from_millis(10));
    }

    let r = http_request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(r.status, 202);
    let v = wait_settled(&addr, id, Duration::from_secs(20));
    assert_eq!(state_of(&v), "cancelled", "{}", v.to_string());
    assert_eq!(
        v.get("residual_sessions").and_then(Json::as_usize),
        Some(0),
        "cancel leaked mux session queues"
    );
    assert!(!Path::new(&dir).exists(), "cancelled job left its checkpoint behind");

    // the daemon is still fully serving
    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200);
    d.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// The acceptance regression: a deliberately panicked session settles
/// as a typed `failed` job with no checkpoint file behind, and the
/// daemon goes on to run the same job cleanly.
#[test]
fn panicking_session_does_not_kill_the_daemon_and_leaves_no_checkpoint() {
    let root = tempdir("panic");
    let (d, addr) = daemon(DaemonOptions { checkpoint_root: root.clone(), ..Default::default() });
    let rc = run_config(Backend::Masked, 0xDA05);
    let mut body = job_body(&rc);
    body.set("fault", "panic");
    let id = submit_ok(&addr, &body);
    let v = wait_settled(&addr, id, Duration::from_secs(60));
    assert_eq!(state_of(&v), "failed", "{}", v.to_string());
    let err = v.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(err.contains("panicked"), "error should name the panic: {err}");
    assert!(
        !Path::new(&job_checkpoint_dir(&root, id)).exists(),
        "panicked job left a checkpoint behind"
    );
    let r = http_request(&addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
    assert_eq!(r.status, 409, "failed job has no result");

    // same daemon, same config, no fault: runs to completion
    let id2 = submit_ok(&addr, &job_body(&rc));
    let v2 = wait_settled(&addr, id2, Duration::from_secs(120));
    assert_eq!(state_of(&v2), "done", "{}", v2.to_string());
    assert!(
        !Path::new(&job_checkpoint_dir(&root, id2)).exists(),
        "clean job's checkpoint not removed"
    );
    d.shutdown();
    let _ = std::fs::remove_dir_all(&root);
}

/// Race battery: several client threads submit, immediately poll, and
/// cancel jobs while two workers drain the pool. Every response must be
/// an expected status code (no 500s, no hangs) and every job settles.
#[test]
fn concurrent_submit_cancel_status_battery() {
    let (d, addr) = daemon(DaemonOptions {
        max_jobs: 2,
        queue_cap: 64,
        max_jobs_per_tenant: 64,
        ..Default::default()
    });
    let addr = std::sync::Arc::new(addr);
    let mut handles = Vec::new();
    for th in 0..4u64 {
        let addr = std::sync::Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            for i in 0..6u64 {
                let rc = run_config(Backend::Plaintext, 0xBA77 + th * 100 + i);
                let mut body = job_body(&rc);
                body.set("hold_ms", 5usize).set("tenant", format!("t{th}"));
                let r = submit(&addr, &body);
                assert!(
                    r.status == 201 || r.status == 429,
                    "submit: HTTP {} {}",
                    r.status,
                    String::from_utf8_lossy(&r.body)
                );
                if r.status != 201 {
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
                let v = r.json_body().unwrap();
                let id = v.get("job").and_then(Json::as_usize).unwrap() as u64;
                let s = http_request(&addr, "GET", &format!("/jobs/{id}"), None).unwrap();
                assert_eq!(s.status, 200);
                // cancel roughly half the jobs, racing the workers
                if i % 2 == 0 {
                    let c = http_request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
                    assert!(c.status == 200 || c.status == 202, "cancel: HTTP {}", c.status);
                }
                let s = http_request(&addr, "GET", &format!("/jobs/{id}"), None).unwrap();
                assert_eq!(s.status, 200);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // every job drains to a terminal state, none wedged
    let t0 = Instant::now();
    loop {
        let h = http_request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(h.status, 200);
        let v = h.json_body().unwrap();
        let active = v.get("queued").and_then(Json::as_usize).unwrap()
            + v.get("running").and_then(Json::as_usize).unwrap();
        if active == 0 {
            // nothing failed: no faults were injected
            assert_eq!(v.get("failed").and_then(Json::as_usize), Some(0), "{}", v.to_string());
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(120), "jobs wedged: {}", v.to_string());
        std::thread::sleep(Duration::from_millis(50));
    }
    d.shutdown();
}
