//! E3 — combine-stage cost is independent of sample size: `O(PK² + K³)`
//! (+ `O(K²M)` for the scan projection), and E10 aggregation-backend
//! comparison at fixed layout.
//!
//! Rows regenerated:
//!   combine/N=...        combine runtime flat across N (fixed K, M, P)
//!   combine/K=...        growth in K at fixed M
//!   combine/P=...        TSQR stack growth in party count
//!   combine/backend=...  plaintext-sum vs masked-decode vs shamir-reconstruct

use dash::linalg::Matrix;
use dash::mpc::fixed::FixedCodec;
use dash::mpc::masking::{aggregate_masked, PairwiseMasker};
use dash::scan::{
    combine_compressed, compress_party, flatten_for_sum, unflatten_sum, CombineOptions,
    CompressedParty, RFactorMethod,
};
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn party(n: usize, k: usize, m: usize, seed: u64) -> CompressedParty {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    let x = Matrix::randn(n, m, &mut rng);
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    compress_party(&ys, &c, &x, 256, None)
}

fn aggregate(cps: &[CompressedParty]) -> dash::scan::AggregateSums {
    let (layout, mut acc) = flatten_for_sum(&cps[0]);
    for cp in &cps[1..] {
        let (_, f) = flatten_for_sum(cp);
        for (a, b) in acc.iter_mut().zip(&f) {
            *a += b;
        }
    }
    unflatten_sum(layout, &acc).unwrap()
}

fn main() {
    let mut b = Bench::new("combine");
    let k = 12;
    let m = 2048;

    // --- combine flat in N: same K/M layout, aggregates from various N ---
    for &n in &[1_000usize, 10_000, 100_000] {
        let cp = party(n, k, m, 50);
        let agg = aggregate(std::slice::from_ref(&cp));
        let rs = vec![cp.r.clone()];
        b.case(&format!("N={n}"), || {
            std::hint::black_box(
                combine_compressed(&agg, Some(&rs), CombineOptions::default()).unwrap(),
            );
        });
    }

    // --- growth in K ---
    for &kk in &[4usize, 12, 24] {
        let cp = party(4000, kk, m, 51);
        let agg = aggregate(std::slice::from_ref(&cp));
        let rs = vec![cp.r.clone()];
        b.case(&format!("K={kk}"), || {
            std::hint::black_box(
                combine_compressed(&agg, Some(&rs), CombineOptions::default()).unwrap(),
            );
        });
    }

    // --- TSQR stack growth in P ---
    for &p in &[2usize, 8, 32] {
        let cps: Vec<CompressedParty> =
            (0..p).map(|i| party(500, k, 64, 60 + i as u64)).collect();
        let agg = aggregate(&cps);
        let rs: Vec<Matrix> = cps.iter().map(|c| c.r.clone()).collect();
        b.case(&format!("P={p}"), || {
            std::hint::black_box(
                combine_compressed(
                    &agg,
                    Some(&rs),
                    CombineOptions { r_method: RFactorMethod::Tsqr },
                )
                .unwrap(),
            );
        });
    }

    // --- aggregation backends at fixed layout (P=4, K=12, M=2048) ---
    let p = 4;
    let cps: Vec<CompressedParty> = (0..p).map(|i| party(800, k, m, 70 + i as u64)).collect();
    let flats: Vec<Vec<f64>> = cps.iter().map(|c| flatten_for_sum(c).1).collect();
    let len = flats[0].len();

    b.case_units("backend=plaintext-sum", Some(len as f64), "elem", || {
        let mut acc = vec![0.0f64; len];
        for f in &flats {
            for (a, v) in acc.iter_mut().zip(f) {
                *a += v;
            }
        }
        std::hint::black_box(acc);
    });

    let codec = FixedCodec::default();
    let mut rng = Rng::new(71);
    let seeds = PairwiseMasker::session_seeds(p, &mut rng);
    // pre-encode+mask (party-side cost measured in bench_mpc); here we
    // time the leader: aggregate + decode
    let masked: Vec<Vec<u64>> = flats
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let mut enc = codec.encode_vec(f).unwrap();
            PairwiseMasker::new(i, p, seeds[i].clone()).mask_in_place(&mut enc);
            enc
        })
        .collect();
    b.case_units("backend=masked-leader", Some(len as f64), "elem", || {
        let sum = aggregate_masked(&masked);
        std::hint::black_box(codec.decode_vec(&sum));
    });

    // party-side masking cost for the same payload
    b.case_units("backend=masked-party", Some(len as f64), "elem", || {
        let mut enc = codec.encode_vec(&flats[0]).unwrap();
        PairwiseMasker::new(0, p, seeds[0].clone()).mask_in_place(&mut enc);
        std::hint::black_box(enc);
    });

    b.save_report();
}
