//! E9 — combine-stage linear algebra: TSQR vs Gram+Cholesky ablation
//! (accuracy under ill-conditioning + cost), plus substrate throughput.
//!
//! Rows regenerated:
//!   linalg/qr/...            Householder QR cost (the O(N_p K²) term)
//!   linalg/tsqr/P=...        stacked-R re-factorization cost
//!   linalg/cholesky/K=...    Gram factorization cost
//!   ablation table           ‖R−R_true‖/‖R‖ for TSQR vs Cholesky vs cond(C)

use dash::linalg::{cholesky_upper, householder_qr, rel_err, tsqr_stack_r, Matrix};
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn main() {
    let mut b = Bench::new("linalg");
    let mut rng = Rng::new(110);

    // QR cost: the per-party compress term O(N_p K²)
    for &(n, k) in &[(1_000usize, 8usize), (10_000, 8), (10_000, 24)] {
        let a = Matrix::randn(n, k, &mut rng);
        b.case_units(&format!("qr/N={n},K={k}"), Some((n * k * k) as f64), "flop", || {
            std::hint::black_box(householder_qr(&a));
        });
    }

    // TSQR stack cost vs party count
    let k = 12;
    for &p in &[4usize, 16, 64] {
        let rs: Vec<Matrix> = (0..p)
            .map(|i| householder_qr(&Matrix::randn(200, k, &mut rng.derive(i as u64))).r)
            .collect();
        b.case(&format!("tsqr/P={p},K={k}"), || {
            std::hint::black_box(tsqr_stack_r(&rs));
        });
    }

    // Cholesky cost vs K
    for &kk in &[8usize, 16, 32] {
        let a = Matrix::randn(4 * kk, kk, &mut rng);
        let g = a.gram();
        b.case(&format!("cholesky/K={kk}"), || {
            std::hint::black_box(cholesky_upper(&g).unwrap());
        });
    }

    // --- E9 ablation: accuracy vs conditioning ---
    println!("\nE9 — R-factor accuracy vs conditioning (P=3, K=6, N_p=200):");
    println!(
        "{:>12} {:>16} {:>16} {:>12}",
        "col_noise", "tsqr_rel_err", "chol_rel_err", "chol/tsqr"
    );
    let parties = 3;
    let kk = 6;
    let n_per = 200;
    for &eps in &[1.0f64, 1e-3, 1e-5, 1e-7, 1e-9] {
        let mut cs = Vec::new();
        for i in 0..parties {
            let mut c = Matrix::randn(n_per, kk, &mut rng.derive(1000 + i as u64));
            for r in 0..n_per {
                c[(r, 0)] = 1.0;
                // last column nearly dependent on column 1
                c[(r, kk - 1)] = c[(r, 1)] + eps * c[(r, kk - 1)];
            }
            cs.push(c);
        }
        let refs: Vec<&Matrix> = cs.iter().collect();
        let r_true = householder_qr(&Matrix::vstack(&refs)).r;
        let rs: Vec<Matrix> = cs.iter().map(|c| householder_qr(c).r).collect();
        let r_tsqr = tsqr_stack_r(&rs);
        let mut gram = Matrix::zeros(kk, kk);
        for c in &cs {
            gram = gram.add(&c.gram());
        }
        match cholesky_upper(&gram) {
            Ok(r_chol) => {
                let e_t = rel_err(&r_tsqr.data, &r_true.data);
                let e_c = rel_err(&r_chol.data, &r_true.data);
                println!(
                    "{:>12.0e} {:>16.2e} {:>16.2e} {:>12.1}",
                    eps,
                    e_t,
                    e_c,
                    e_c / e_t.max(1e-18)
                );
            }
            Err(_) => {
                let e_t = rel_err(&r_tsqr.data, &r_true.data);
                println!(
                    "{:>12.0e} {:>16.2e} {:>16} {:>12}",
                    eps, e_t, "FAILED (SPD)", "-"
                );
            }
        }
    }
    println!("(TSQR tracks the true R as cond(C) degrades; Cholesky of the Gram");
    println!(" matrix squares the condition number — why the plaintext path uses");
    println!(" Lemma 4.1 and the secure path documents the trade-off)");

    b.save_report();
}
