//! Session-service throughput: serial dedicated-connection runs vs the
//! multiplexed SessionManager at increasing concurrency, plus the
//! multiplexing byte overhead and the shared-engine lowering accounting,
//! plus the high-connection-count reactor-vs-threaded sweep
//! (c ∈ {64, 256, 1024} concurrent sessions, sessions/s and
//! transport-threads-spawned per drive mode). Writes
//! `BENCH_sessions.json` (bench rows + summary rows) for
//! EXPERIMENTS.md §E11/§E13.

use dash::coordinator::{
    run_multi_party_scan_t, run_session_batch, BatchOptions, SessionSpec, Transport,
};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::net::{transport_driver_threads, FRAME_V2_OVERHEAD};
use dash::runtime::ArtifactExec;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;
use dash::util::json::Json;

fn spec(parties: usize, n_per: usize, m: usize, t: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_per; parties],
        m_variants: m,
        n_traits: t,
        n_causal: 3,
        effect_sd: 0.4,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let (n_per, m) = if quick { (60, 96) } else { (200, 480) };
    let sessions = if quick { 4 } else { 8 };
    let cohort = generate_cohort(&spec(3, n_per, m, 2), 0xE11);
    // one compress thread per party so session-level parallelism—not
    // intra-party parallelism—is what the concurrency sweep measures
    let cfg = ScanConfig {
        backend: Backend::Masked,
        shard_m: 32,
        block_m: 32,
        threads: Some(1),
        ..ScanConfig::default()
    };
    let specs: Vec<SessionSpec> =
        (0..sessions).map(|i| SessionSpec { cfg: cfg.clone(), seed: 40 + i as u64 }).collect();

    let mut b = Bench::new("sessions");
    let mut rows: Vec<(String, f64)> = Vec::new();

    // serial baseline: one dedicated-connection run after another
    let label = format!("serial_x{sessions}");
    let serial_s = b
        .case_units(&label, Some(sessions as f64), "sess", || {
            for s in &specs {
                std::hint::black_box(
                    run_multi_party_scan_t(&cohort, &s.cfg, Transport::InProc, s.seed)
                        .unwrap(),
                );
            }
        })
        .median_s;
    rows.push((label, serial_s));

    // multiplexed: same sessions over shared connections, swept over
    // the worker-pool bound
    for conc in [1usize, 4, sessions] {
        if conc > sessions {
            continue;
        }
        let label = format!("mux_x{sessions}_c{conc}");
        let mux_s = b
            .case_units(&label, Some(sessions as f64), "sess", || {
                let batch = run_session_batch(
                    &cohort,
                    &specs,
                    &BatchOptions { max_concurrent: conc, ..Default::default() },
                )
                .unwrap();
                assert!(batch.runs.iter().all(|r| r.is_ok()));
                std::hint::black_box(batch);
            })
            .median_s;
        rows.push((label, mux_s));
    }

    // the same batch driven by the epoll reactor instead of pump
    // threads (linux-only): one readiness thread for every connection
    if cfg!(target_os = "linux") {
        let label = format!("mux_x{sessions}_c{sessions}_reactor");
        let mux_s = b
            .case_units(&label, Some(sessions as f64), "sess", || {
                let batch = run_session_batch(
                    &cohort,
                    &specs,
                    &BatchOptions {
                        transport: Transport::Reactor,
                        max_concurrent: sessions,
                        ..Default::default()
                    },
                )
                .unwrap();
                assert!(batch.runs.iter().all(|r| r.is_ok()));
                std::hint::black_box(batch);
            })
            .median_s;
        rows.push((label, mux_s));
    }

    // Byte overhead: per-session bytes under multiplexing vs serial —
    // exactly the v2 envelope per frame.
    let serial_run =
        run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 40).unwrap();
    let batch = run_session_batch(
        &cohort,
        &specs[..1],
        &BatchOptions { max_concurrent: 1, ..Default::default() },
    )
    .unwrap();
    let mux_run = batch.runs[0].as_ref().unwrap();
    let frames = mux_run.metrics.messages_total;
    let overhead = mux_run.metrics.bytes_total as i64 - serial_run.metrics.bytes_total as i64;
    assert_eq!(
        overhead,
        (frames * FRAME_V2_OVERHEAD) as i64,
        "multiplexing overhead must be exactly 12 bytes per frame"
    );

    // Shared-engine lowering: an artifact-mode batch lowers each entry
    // once for all sessions.
    let mut art = cfg.clone();
    art.use_artifacts = true;
    art.artifact_exec = ArtifactExec::Reference;
    let art_specs: Vec<SessionSpec> =
        (0..sessions).map(|i| SessionSpec { cfg: art.clone(), seed: 40 + i as u64 }).collect();
    let art_batch = run_session_batch(
        &cohort,
        &art_specs,
        &BatchOptions { max_concurrent: 4.min(sessions), ..Default::default() },
    )
    .unwrap();
    assert!(art_batch.runs.iter().all(|r| r.is_ok()));
    let lowered_per_party = art_batch.party_kernels[0].lowered_entries();
    let xpasses_per_party = art_batch.party_kernels[0].xside_passes();

    // High-connection-count sweep (EXPERIMENTS.md §E13): c concurrent
    // tiny sessions, reactor vs threaded pumps, sessions/s plus the
    // transport threads each drive mode spawned (the reactor must stay
    // O(1) regardless of c). Single-shot wall time per cell — the cells
    // are scheduling-dominated, and c=1024 is too heavy to repeat.
    let sweep_cohort = generate_cohort(&spec(3, 24, 16, 1), 0xE13);
    let sweep_cfg = ScanConfig {
        backend: Backend::Masked,
        shard_m: 8,
        block_m: 8,
        threads: Some(1),
        ..ScanConfig::default()
    };
    let sweep_cs: &[usize] = if quick { &[64] } else { &[64, 256, 1024] };
    let mut sweep_transports = vec![Transport::Tcp];
    if cfg!(target_os = "linux") {
        sweep_transports.push(Transport::Reactor);
    }
    // (c, transport, wall_s, sessions/s, transport threads spawned)
    let mut sweep: Vec<(usize, Transport, f64, f64, u64)> = Vec::new();
    for &c in sweep_cs {
        let sweep_specs: Vec<SessionSpec> = (0..c)
            .map(|i| SessionSpec { cfg: sweep_cfg.clone(), seed: 9000 + i as u64 })
            .collect();
        for &transport in &sweep_transports {
            let before = transport_driver_threads();
            let batch = run_session_batch(
                &sweep_cohort,
                &sweep_specs,
                &BatchOptions {
                    transport,
                    max_concurrent: c,
                    // generous per-frame deadline: at c=1024 the box is
                    // scheduling thousands of session workers
                    recv_timeout: Some(std::time::Duration::from_secs(300)),
                    ..Default::default()
                },
            )
            .unwrap();
            let drivers = transport_driver_threads() - before;
            assert!(batch.runs.iter().all(|r| r.is_ok()), "c={c} {transport:?}");
            sweep.push((c, transport, batch.wall_s, c as f64 / batch.wall_s, drivers));
        }
    }

    // human summary
    let serial_tp = sessions as f64 / serial_s;
    println!("\nsession throughput (P=3, N={}, M={m}, T=2, masked):", 3 * n_per);
    println!("{:>16} {:>10} {:>12} {:>10}", "case", "median_s", "sess/s", "vs serial");
    for (label, s) in &rows {
        let tp = sessions as f64 / *s;
        println!("{:>16} {:>10.4} {:>12.2} {:>9.2}x", label, s, tp, tp / serial_tp);
    }
    println!(
        "bytes/session     serial {} vs multiplexed {} (+{} = {} frames × {}B envelope)",
        serial_run.metrics.bytes_total,
        mux_run.metrics.bytes_total,
        overhead,
        frames,
        FRAME_V2_OVERHEAD
    );
    println!(
        "shared engine     {lowered_per_party} lowered entries serve {} sessions \
         ({xpasses_per_party} X-passes/party, no per-session recompiles)",
        sessions
    );
    println!("\nhigh-connection sweep (P=3, tiny sessions, E13):");
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>16}",
        "c", "transport", "wall_s", "sess/s", "driver_threads"
    );
    for &(c, t, wall, tp, drivers) in &sweep {
        println!(
            "{:>6} {:>10} {:>10.3} {:>12.1} {:>16}",
            c,
            dash::config::transport_name(t),
            wall,
            tp,
            drivers
        );
    }

    // machine-readable report
    let mut report = b.json_lines();
    for (label, s) in &rows {
        let mut o = Json::obj();
        o.set("group", "sessions")
            .set("row", "throughput")
            .set("label", label.as_str())
            .set("sessions", sessions)
            .set("median_s", *s)
            .set("sessions_per_s", sessions as f64 / *s)
            .set("speedup_vs_serial", serial_s / *s);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    for &(c, t, wall, tp, drivers) in &sweep {
        let mut o = Json::obj();
        o.set("group", "sessions")
            .set("row", "sweep")
            .set("transport", dash::config::transport_name(t))
            .set("sessions", c)
            .set("wall_s", wall)
            .set("sessions_per_s", tp)
            .set("driver_threads", drivers as usize);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    let mut o = Json::obj();
    o.set("group", "sessions")
        .set("row", "overhead")
        .set("serial_bytes", serial_run.metrics.bytes_total)
        .set("mux_bytes_per_session", mux_run.metrics.bytes_total)
        .set("frames_per_session", frames)
        .set("envelope_bytes_per_frame", FRAME_V2_OVERHEAD)
        .set("shared_engine_lowered_entries", lowered_per_party as usize)
        .set("shared_engine_xside_passes", xpasses_per_party as usize)
        .set("per_session_recompiles", 0usize);
    report.push_str(&o.to_string());
    report.push('\n');
    if let Err(e) = std::fs::write("BENCH_sessions.json", &report) {
        eprintln!("warn: could not write BENCH_sessions.json: {e}");
    } else {
        println!("report: BENCH_sessions.json");
    }
}
