//! Shard-width sweep: the streaming pipeline against the single-shot
//! baseline (tentpole claim — bounded rounds, identical answers).
//!
//! For each shard width the full multi-party session is timed and its
//! communication shape recorded. Expectations:
//!
//! - `bytes_total` is ~constant across widths (same statistics move,
//!   plus a few bytes of per-shard framing);
//! - `bytes_max_round` — the peak payload of any single contribution
//!   round, which bounds leader/party working memory — scales with the
//!   shard width, not with M;
//! - outputs are bit-identical to the single-shot run at every width.
//!
//! Output: human table + JSON lines via `util::bench` appended with
//! per-width communication rows, written to `BENCH_scan.json`.
//!
//! Run: `cargo bench --bench bench_shard` (DASH_BENCH_QUICK=1 for CI).

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;
use dash::util::human_bytes;
use dash::util::json::Json;

fn spec(n_total: usize, parties: usize, m: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 10.min(m),
        effect_sd: 0.2,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn cfg(shard_m: usize) -> ScanConfig {
    ScanConfig { backend: Backend::Masked, shard_m, ..Default::default() }
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let parties = 3;
    let (n, m) = if quick { (600, 4096) } else { (2000, 16384) };
    // 0 = single-shot baseline (one shard over all of M)
    let widths: &[usize] =
        if quick { &[0, 512, 2048] } else { &[0, 256, 1024, 4096, 16384] };

    eprintln!("generating cohort: P={parties} N={n} M={m} ...");
    let cohort = generate_cohort(&spec(n, parties, m), 90);
    let baseline = run_multi_party_scan_t(&cohort, &cfg(0), Transport::InProc, 5).unwrap();

    let mut b = Bench::new("shard");
    struct Row {
        label: String,
        width: usize,
        shards: usize,
        median_s: f64,
        bytes_total: u64,
        bytes_max_round: u64,
        mismatches: usize,
    }
    let mut rows: Vec<Row> = Vec::new();

    for &w in widths {
        let label = if w == 0 { "single-shot".to_string() } else { format!("width={w}") };
        let res = run_multi_party_scan_t(&cohort, &cfg(w), Transport::InProc, 5).unwrap();
        // exactness: every width must reproduce the baseline bit-for-bit
        let mismatches = (0..m)
            .filter(|&j| {
                res.output.assoc[0].beta[j].to_bits() != baseline.output.assoc[0].beta[j].to_bits()
                    || res.output.assoc[0].se[j].to_bits()
                        != baseline.output.assoc[0].se[j].to_bits()
            })
            .count();
        let median_s = b
            .case_units(&label, Some(m as f64), "var", || {
                std::hint::black_box(
                    run_multi_party_scan_t(&cohort, &cfg(w), Transport::InProc, 5).unwrap(),
                );
            })
            .median_s;
        rows.push(Row {
            label,
            width: if w == 0 { m } else { w },
            shards: res.metrics.shards,
            median_s,
            bytes_total: res.metrics.bytes_total,
            bytes_max_round: res.metrics.bytes_max_round,
            mismatches,
        });
    }

    println!("\nshard-width sweep (P={parties}, N={n}, M={m}, masked backend):");
    println!(
        "{:>12} {:>7} {:>10} {:>14} {:>16} {:>10}",
        "width", "shards", "median_s", "bytes_total", "peak_round_bytes", "mismatch"
    );
    for r in &rows {
        println!(
            "{:>12} {:>7} {:>10.4} {:>14} {:>16} {:>10}",
            r.width,
            r.shards,
            r.median_s,
            human_bytes(r.bytes_total),
            human_bytes(r.bytes_max_round),
            r.mismatches
        );
    }
    println!("(peak round bytes track the shard width, not M — the bounded-memory claim;");
    println!(" mismatch must be 0: sharded == single-shot bit-for-bit)");

    // Machine-readable report: bench measurements + per-width comm rows.
    let mut report = b.json_lines();
    for r in &rows {
        let mut o = Json::obj();
        o.set("group", "shard")
            .set("row", "comm")
            .set("label", r.label.as_str())
            .set("width", r.width)
            .set("shards", r.shards)
            .set("median_s", r.median_s)
            .set("bytes_total", r.bytes_total)
            .set("bytes_max_round", r.bytes_max_round)
            .set("mismatches", r.mismatches);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    if let Err(e) = std::fs::write("BENCH_scan.json", &report) {
        eprintln!("warn: could not write BENCH_scan.json: {e}");
    } else {
        println!("report: BENCH_scan.json");
    }

    let any_mismatch = rows.iter().any(|r| r.mismatches > 0);
    assert!(!any_mismatch, "sharded scan diverged from single-shot baseline");
}
