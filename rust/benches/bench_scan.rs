//! E1 (headline) + E4 — full multi-party scans: secure vs plaintext
//! total runtime as N grows (overhead ratio → 1 = "plaintext speed"),
//! and measured communication vs M and vs N.
//!
//! Rows regenerated:
//!   scan/{masked,plaintext}/N=...  end-to-end session wall time
//!   scan/overhead/N=...            printed ratio table (E1 headline)
//!   scan/comm/M=...                bytes vs M (E4: linear, N-independent)

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;

fn spec(n_total: usize, parties: usize, m: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 10.min(m),
        effect_sd: 0.2,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn cfg(backend: Backend) -> ScanConfig {
    ScanConfig { backend, block_m: 256, ..Default::default() }
}

fn main() {
    let mut b = Bench::new("scan");
    let parties = 4;
    let m = 2048;
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let ns: &[usize] = if quick {
        &[1_000, 4_000, 16_000]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 256_000]
    };

    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for &n in ns {
        let cohort = generate_cohort(&spec(n, parties, m), 80);
        let masked = b
            .case(&format!("masked/N={n}"), || {
                std::hint::black_box(
                    run_multi_party_scan_t(&cohort, &cfg(Backend::Masked), Transport::InProc, 1)
                        .unwrap(),
                );
            })
            .median_s;
        let plain = b
            .case(&format!("plaintext/N={n}"), || {
                std::hint::black_box(
                    run_multi_party_scan_t(&cohort, &cfg(Backend::Plaintext), Transport::InProc, 1)
                        .unwrap(),
                );
            })
            .median_s;
        rows.push((n, masked, plain));
    }

    println!("\nE1 headline — secure/plaintext overhead ratio (P={parties}, M={m}, K=5):");
    println!("{:>10} {:>12} {:>12} {:>10}", "N", "masked_s", "plaintext_s", "ratio");
    for (n, masked, plain) in &rows {
        println!("{:>10} {:>12.4} {:>12.4} {:>10.3}", n, masked, plain, masked / plain);
    }
    println!("(ratio → 1 as N grows: SMC cost is O(M), compress is O(N·M))");

    // --- E4: communication vs M and vs N ---
    println!("\nE4 — inter-party bytes (masked backend):");
    println!("{:>8} {:>8} {:>14} {:>14}", "N", "M", "bytes_total", "bytes/variant");
    let ms: &[usize] = if quick { &[512, 2048] } else { &[512, 2048, 8192] };
    for &mm in ms {
        let cohort = generate_cohort(&spec(2_000, parties, mm), 81);
        let res =
            run_multi_party_scan_t(&cohort, &cfg(Backend::Masked), Transport::InProc, 2).unwrap();
        println!(
            "{:>8} {:>8} {:>14} {:>14.1}",
            2_000,
            mm,
            res.metrics.bytes_total,
            res.metrics.bytes_total as f64 / mm as f64
        );
    }
    // N-independence: same M, 8x the samples
    for &n in &[2_000usize, 16_000] {
        let cohort = generate_cohort(&spec(n, parties, 2048), 82);
        let res =
            run_multi_party_scan_t(&cohort, &cfg(Backend::Masked), Transport::InProc, 3).unwrap();
        println!(
            "{:>8} {:>8} {:>14} {:>14.1}",
            n,
            2048,
            res.metrics.bytes_total,
            res.metrics.bytes_total as f64 / 2048.0
        );
    }
    println!("(bytes grow with M, not with N — the O(M) claim; naive raw-data");
    println!(" sharing would be O(N·M): see bench_mpc/naive-dot rows)");

    b.save_report();
}
