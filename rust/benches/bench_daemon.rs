//! Control-plane throughput (E15): jobs/s through the daemon's HTTP
//! submit → poll → fetch path vs the same work as direct in-process
//! single-session batches, plus the raw HTTP/registry op rate. Writes
//! `BENCH_daemon.json` for EXPERIMENTS.md §E15.

use dash::config::RunConfig;
use dash::coordinator::{run_session_batch, BatchOptions, Daemon, DaemonOptions, SessionSpec};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::net::http::http_request;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;
use dash::util::json::Json;

fn spec(parties: usize, n_per: usize, m: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_per; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 3,
        effect_sd: 0.4,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn submit(addr: &str, body: &Json) -> u64 {
    let r = http_request(addr, "POST", "/jobs", Some(body.to_string().as_bytes())).unwrap();
    assert_eq!(r.status, 201, "submit: {}", String::from_utf8_lossy(&r.body));
    r.json_body().unwrap().get("job").and_then(Json::as_usize).unwrap() as u64
}

fn wait_and_fetch(addr: &str, id: u64) {
    loop {
        let v = http_request(addr, "GET", &format!("/jobs/{id}"), None)
            .unwrap()
            .json_body()
            .unwrap();
        match v.get("status").and_then(Json::as_str) {
            Some("done") => break,
            Some("queued") | Some("running") => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            other => panic!("job {id} settled as {other:?}"),
        }
    }
    let r = http_request(addr, "GET", &format!("/jobs/{id}/result"), None).unwrap();
    assert_eq!(r.status, 200);
    std::hint::black_box(r);
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let (n_per, m) = if quick { (40, 48) } else { (120, 192) };
    let jobs = if quick { 4usize } else { 8 };
    let cohort_spec = spec(3, n_per, m);
    let cohort = generate_cohort(&cohort_spec, 0xE15);
    let scan = ScanConfig {
        backend: Backend::Masked,
        shard_m: 32,
        block_m: 32,
        threads: Some(1),
        ..ScanConfig::default()
    };
    let rc = RunConfig {
        cohort: cohort_spec,
        scan: scan.clone(),
        seed: 0xE15,
        ..RunConfig::default()
    };
    let mut body = Json::obj();
    body.set("config", rc.to_json());

    let mut b = Bench::new("daemon");

    // baseline: the same jobs as direct in-process single-session
    // batches, serially — what each daemon worker does minus HTTP,
    // registry, and cohort regeneration
    let direct_label = format!("direct_x{jobs}");
    let direct_s = b
        .case_units(&direct_label, Some(jobs as f64), "job", || {
            for _ in 0..jobs {
                let specs = vec![SessionSpec { cfg: scan.clone(), seed: 0xE15 }];
                let batch = run_session_batch(
                    &cohort,
                    &specs,
                    &BatchOptions { max_concurrent: 1, ..Default::default() },
                )
                .unwrap();
                assert!(batch.runs.iter().all(|r| r.is_ok()));
                std::hint::black_box(batch);
            }
        })
        .median_s;

    let daemon = Daemon::start(DaemonOptions {
        max_jobs: 2,
        queue_cap: jobs,
        max_jobs_per_tenant: jobs + 2,
        ..Default::default()
    })
    .unwrap();
    let addr = daemon.addr().to_string();

    // the full control-plane path: submit everything, then drain —
    // jobs pipeline through the two workers
    let daemon_label = format!("daemon_x{jobs}_c2");
    let daemon_s = b
        .case_units(&daemon_label, Some(jobs as f64), "job", || {
            let ids: Vec<u64> = (0..jobs).map(|_| submit(&addr, &body)).collect();
            for id in ids {
                wait_and_fetch(&addr, id);
            }
        })
        .median_s;

    // raw control-plane op rate, no scans involved
    let ops = 100usize;
    let ops_s = b
        .case_units("healthz_x100", Some(ops as f64), "op", || {
            for _ in 0..ops {
                let r = http_request(&addr, "GET", "/healthz", None).unwrap();
                assert_eq!(r.status, 200);
            }
        })
        .median_s;
    daemon.shutdown();

    let mut report = String::new();
    for (row, wall) in [("direct", direct_s), ("daemon", daemon_s)] {
        let mut o = Json::obj();
        o.set("group", "daemon")
            .set("row", row)
            .set("jobs", jobs)
            .set("wall_s", wall)
            .set("jobs_per_s", jobs as f64 / wall);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    let mut o = Json::obj();
    o.set("group", "daemon")
        .set("row", "http_ops")
        .set("ops_per_s", ops as f64 / ops_s);
    report.push_str(&o.to_string());
    report.push('\n');
    if let Err(e) = std::fs::write("BENCH_daemon.json", &report) {
        eprintln!("warn: could not write BENCH_daemon.json: {e}");
    } else {
        println!("report: BENCH_daemon.json");
    }
}
