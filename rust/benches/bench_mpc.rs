//! E10 — SMC primitive micro-costs, plus the E1 crossover evidence:
//! the naive raw-data protocol the paper argues against.
//!
//! Rows regenerated:
//!   mpc/encode, mpc/mask, mpc/additive-share, mpc/shamir-*, mpc/beaver-mul
//!   mpc/naive-dot/N=...   — O(N) Beaver mults per dot product, so the
//!                           naive protocol's cost grows with N while the
//!                           compressed protocol's combine stage is flat.

use dash::mpc::additive;
use dash::mpc::beaver::{additive_share_fe, deal_triple, multiply_shared};
use dash::mpc::field::{random_fe, Fe};
use dash::mpc::fixed::FixedCodec;
use dash::mpc::masking::{aggregate_masked, PairwiseMasker};
use dash::mpc::naive::{secure_dot, share_real_vec, NaiveCost};
use dash::mpc::shamir;
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn main() {
    let mut b = Bench::new("mpc");
    let mut rng = Rng::new(90);
    let len = 100_000;
    let vals: Vec<f64> = (0..len).map(|_| rng.normal_ms(0.0, 100.0)).collect();
    let codec = FixedCodec::default();

    // fixed-point encode/decode
    b.case_units("encode", Some(len as f64), "elem", || {
        std::hint::black_box(codec.encode_vec(&vals).unwrap());
    });
    let enc = codec.encode_vec(&vals).unwrap();
    b.case_units("decode", Some(len as f64), "elem", || {
        std::hint::black_box(codec.decode_vec(&enc));
    });

    // pairwise masking (P=8)
    let p = 8;
    let seeds = PairwiseMasker::session_seeds(p, &mut rng);
    b.case_units("mask(P=8)", Some(len as f64), "elem", || {
        let mut m = PairwiseMasker::new(0, p, seeds[0].clone());
        let mut v = enc.clone();
        m.mask_in_place(&mut v);
        std::hint::black_box(v);
    });
    let masked: Vec<Vec<u64>> = (0..p)
        .map(|i| {
            let mut m = PairwiseMasker::new(i, p, seeds[i].clone());
            let mut v = enc.clone();
            m.mask_in_place(&mut v);
            v
        })
        .collect();
    b.case_units("aggregate(P=8)", Some(len as f64), "elem", || {
        std::hint::black_box(aggregate_masked(&masked));
    });

    // additive sharing
    b.case_units("additive-share(P=4)", Some(len as f64), "elem", || {
        std::hint::black_box(additive::share_vec(&enc, 4, &mut rng.clone()));
    });

    // Shamir share + reconstruct (smaller vector — O(P²) cost)
    let slen = 10_000;
    let secrets: Vec<Fe> = (0..slen).map(|_| random_fe(&mut rng)).collect();
    b.case_units("shamir-share(P=5,t=3)", Some(slen as f64), "elem", || {
        std::hint::black_box(shamir::share_vec(&secrets, 5, 3, &mut rng.clone()));
    });
    let shares = shamir::share_vec(&secrets, 5, 3, &mut rng);
    let quorum: Vec<&[shamir::Share]> = shares[..3].iter().map(|v| v.as_slice()).collect();
    b.case_units("shamir-reconstruct(t=3)", Some(slen as f64), "elem", || {
        std::hint::black_box(shamir::reconstruct_vec(&quorum));
    });

    // Beaver multiplication
    let blen = 10_000;
    let xs: Vec<Vec<Fe>> = {
        let v: Vec<Fe> = (0..blen).map(|_| random_fe(&mut rng)).collect();
        transpose_shares(&v, 3, &mut rng)
    };
    let ys = {
        let v: Vec<Fe> = (0..blen).map(|_| random_fe(&mut rng)).collect();
        transpose_shares(&v, 3, &mut rng)
    };
    b.case_units("beaver-mul(P=3)", Some(blen as f64), "mul", || {
        let mut acc = Fe(0);
        for i in 0..blen {
            let xi: Vec<Fe> = (0..3).map(|p| xs[p][i]).collect();
            let yi: Vec<Fe> = (0..3).map(|p| ys[p][i]).collect();
            let t = deal_triple(3, &mut rng.clone());
            let z = multiply_shared(&xi, &yi, &t);
            acc = acc.add(z[0]);
        }
        std::hint::black_box(acc);
    });

    // --- naive raw-data baseline: secure dot products scale with N ---
    println!("\nnaive raw-data protocol (paper's comparator): cost per dot product");
    println!("{:>8} {:>12} {:>14} {:>14}", "N", "time", "triples", "opened_elems");
    let codec16 = FixedCodec::new(16);
    for &n in &[64usize, 256, 1024] {
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let xs = share_real_vec(&x, 3, &codec16, &mut rng).unwrap();
        let t0 = std::time::Instant::now();
        let mut cost = NaiveCost::default();
        let iters = 5;
        for _ in 0..iters {
            cost = NaiveCost::default();
            std::hint::black_box(secure_dot(&xs, &xs, 3, &mut rng, &mut cost));
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{:>8} {:>12} {:>14} {:>14}",
            n,
            dash::util::human_secs(dt),
            cost.triples,
            cost.opened_elems
        );
    }
    println!("(the compressed protocol does ZERO secure multiplications for the");
    println!(" same statistics — its combine stage is one secure sum of O(K·M))");

    b.save_report();
}

fn transpose_shares(v: &[Fe], parties: usize, rng: &mut Rng) -> Vec<Vec<Fe>> {
    let mut out: Vec<Vec<Fe>> = (0..parties).map(|_| Vec::with_capacity(v.len())).collect();
    for &s in v {
        for (p, sh) in additive_share_fe(s, parties, rng).into_iter().enumerate() {
            out[p].push(sh);
        }
    }
    out
}
