//! Trait-amortization sweep (§3): one secure session scanning T traits
//! versus T independent single-trait sessions.
//!
//! The economics the paper pitches for biobank PheWAS / eQTL: the
//! `O(NKM)` genotype-side compression and the `O(K²M)` projection are
//! paid once per session, each extra trait adds only `O(N(M+K))` —
//! so the **marginal per-trait cost must fall as T grows**. For each
//! T ∈ {1, 16, 256, 4096} we time the full multi-party session (masked
//! backend, in-process transport) and record wall time, bytes, and the
//! amortized per-trait figures.
//!
//! Output: human table + JSON lines written to `BENCH_multitrait.json`.
//!
//! Run: `cargo bench --bench bench_multitrait` (DASH_BENCH_QUICK=1 for a
//! reduced sweep).

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;
use dash::util::human_bytes;
use dash::util::json::Json;

fn spec(n_total: usize, parties: usize, m: usize, t: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: t,
        n_causal: 5.min(m),
        effect_sd: 0.2,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let parties = 3;
    let (n, m) = if quick { (300, 256) } else { (1200, 1024) };
    let ts: &[usize] = if quick { &[1, 16, 256] } else { &[1, 16, 256, 4096] };
    let shard_m = 128;

    let mut b = Bench::new("multitrait");
    struct Row {
        t: usize,
        median_s: f64,
        per_trait_s: f64,
        bytes_total: u64,
        bytes_per_trait: f64,
        bytes_max_round: u64,
    }
    let mut rows: Vec<Row> = Vec::new();

    for &t in ts {
        eprintln!("generating cohort: P={parties} N={n} M={m} T={t} ...");
        let cohort = generate_cohort(&spec(n, parties, m, t), 95);
        let cfg = ScanConfig { backend: Backend::Masked, shard_m, ..Default::default() };
        let res = run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 5).unwrap();
        assert_eq!(res.output.t(), t);
        let median_s = b
            .case_units(&format!("T={t}"), Some((m * t) as f64), "assoc", || {
                std::hint::black_box(
                    run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 5).unwrap(),
                );
            })
            .median_s;
        rows.push(Row {
            t,
            median_s,
            per_trait_s: median_s / t as f64,
            bytes_total: res.metrics.bytes_total,
            bytes_per_trait: res.metrics.bytes_total as f64 / t as f64,
            bytes_max_round: res.metrics.bytes_max_round,
        });
    }

    println!("\ntrait-amortization sweep (P={parties}, N={n}, M={m}, masked, shard={shard_m}):");
    println!(
        "{:>7} {:>10} {:>14} {:>14} {:>16} {:>16}",
        "T", "median_s", "per_trait_s", "bytes_total", "bytes/trait", "peak_round"
    );
    for r in &rows {
        println!(
            "{:>7} {:>10.4} {:>14.6} {:>14} {:>16.1} {:>16}",
            r.t,
            r.median_s,
            r.per_trait_s,
            human_bytes(r.bytes_total),
            r.bytes_per_trait,
            human_bytes(r.bytes_max_round)
        );
    }
    println!("(per-trait wall time and bytes fall with T: the genotype-side");
    println!(" compression, projection, and CᵀX/X·X traffic are paid once)");

    let mut report = b.json_lines();
    for r in &rows {
        let mut o = Json::obj();
        o.set("group", "multitrait")
            .set("row", "amortization")
            .set("t", r.t)
            .set("median_s", r.median_s)
            .set("per_trait_s", r.per_trait_s)
            .set("bytes_total", r.bytes_total)
            .set("bytes_per_trait", r.bytes_per_trait)
            .set("bytes_max_round", r.bytes_max_round);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    if let Err(e) = std::fs::write("BENCH_multitrait.json", &report) {
        eprintln!("warn: could not write BENCH_multitrait.json: {e}");
    } else {
        println!("report: BENCH_multitrait.json");
    }

    // The amortization claim, asserted: marginal per-trait cost falls
    // monotonically across the sweep, in both time and bytes.
    for pair in rows.windows(2) {
        assert!(
            pair[1].per_trait_s < pair[0].per_trait_s,
            "per-trait time did not fall: T={} {:.6}s vs T={} {:.6}s",
            pair[0].t,
            pair[0].per_trait_s,
            pair[1].t,
            pair[1].per_trait_s
        );
        assert!(
            pair[1].bytes_per_trait < pair[0].bytes_per_trait,
            "per-trait bytes did not fall: T={} vs T={}",
            pair[0].t,
            pair[1].t
        );
    }
}
