//! E7 — incremental updates at cost independent of the original N.
//!
//! Rows regenerated:
//!   incremental/update/N_orig=...     fold-in + recombine (flat in N_orig)
//!   incremental/scratch/N_orig=...    full recompression (linear in N_orig)

use dash::coordinator::IncrementalAggregate;
use dash::linalg::Matrix;
use dash::scan::{compress_party, CompressedParty};
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn party(n: usize, k: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    let x = Matrix::randn(n, m, &mut rng);
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    (ys, c, x)
}

fn compress(d: &(Matrix, Matrix, Matrix)) -> CompressedParty {
    compress_party(&d.0, &d.1, &d.2, 256, None)
}

fn main() {
    let mut b = Bench::new("incremental");
    let k = 6;
    let m = 1024;
    let n_new = 1_000;
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let origs: &[usize] = if quick { &[4_000, 16_000] } else { &[4_000, 16_000, 64_000] };

    let joiner = party(n_new, k, m, 999);
    for &n_orig in origs {
        // initial consortium of 4 parties
        let originals: Vec<_> = (0..4).map(|i| party(n_orig / 4, k, m, 100 + i)).collect();
        let initial: Vec<CompressedParty> = originals.iter().map(compress).collect();
        let base = IncrementalAggregate::from_parties(&initial).unwrap();

        // incremental path: compress ONLY the joiner, fold, recombine
        b.case(&format!("update/N_orig={n_orig}"), || {
            let mut inc = base.clone();
            let jcp = compress(&joiner);
            inc.add_parties(std::slice::from_ref(&jcp)).unwrap();
            std::hint::black_box(inc.recombine().unwrap());
        });

        // from-scratch path: recompress everything
        b.case(&format!("scratch/N_orig={n_orig}"), || {
            let mut all: Vec<CompressedParty> = originals.iter().map(compress).collect();
            all.push(compress(&joiner));
            let agg = IncrementalAggregate::from_parties(&all).unwrap();
            std::hint::black_box(agg.recombine().unwrap());
        });
    }

    println!("\n(update rows are flat in N_orig — cost ∝ N_new + K²M only;");
    println!(" scratch rows grow ∝ N_orig: the paper's fn.1 claim)");
    b.save_report();
}
