//! E2 — compress-stage scaling: `O(N_p K²) + O(N K M / C)`.
//!
//! Rows regenerated:
//!   compress/N=...        runtime linear in N (fixed K, M)
//!   compress/threads=...  runtime ∝ 1/C (fixed N, K, M)
//!   compress/K=...        quadratic-in-K term at fixed N·M
//!   compress/engine=...   pure-Rust vs artifact kernel-suite paths
//!   roofline              bytes-read throughput vs machine copy bandwidth
//!
//! Plus the artifact-suite rows (E10) → `BENCH_artifact.json`:
//!   artifact/whole-M vs artifact/per-shard (streaming entry dispatch)
//!   artifact/T=...        trait batching: one X-side pass regardless of T
//!
//! Plus the threaded tiled-compress rows (E12) → `BENCH_compress.json`:
//!   compress-threaded/shard_m=.../threads=...   serial vs threaded sweep
//! This sweep doubles as the CI divergence gate: every threaded output is
//! asserted bit-identical to the serial bits (kernel-level and through a
//! full e2e sharded scan) — any divergence panics and fails the bench.
//!
//! `DASH_BENCH_QUICK=1` shrinks measurement windows ~10x.

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::linalg::Matrix;
use dash::mpc::Backend;
use dash::runtime::{Engine, KernelMeter, ShapePolicy};
use dash::scan::{
    compress_party, compress_variant_block_opts, compress_yside, ScanConfig, ShardPlan,
    VariantBlockStats,
};
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn data(n: usize, k: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    // genotype-like dosages: exercises the sparsity fast path realistically
    let mut x = Matrix::zeros(n, m);
    for v in x.data.iter_mut() {
        *v = rng.binomial(2, 0.3) as f64;
    }
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    (ys, c, x)
}

fn main() {
    let mut b = Bench::new("compress");
    let k = 8;
    let m = 1024;

    // --- scaling in N (expect ~linear) ---
    for &n in &[1024usize, 4096, 16384] {
        let (y, c, x) = data(n, k, m, 42);
        b.case_units(&format!("N={n}"), Some((n * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- scaling in threads (expect ∝ 1/C) ---
    let (y, c, x) = data(8192, k, m, 43);
    for &threads in &[1usize, 2, 4, 8] {
        b.case_units(
            &format!("threads={threads}"),
            Some((8192 * m) as f64),
            "cell",
            || {
                std::hint::black_box(compress_party(&y, &c, &x, 128, Some(threads)));
            },
        );
    }

    // --- scaling in K at fixed N, M ---
    for &kk in &[2usize, 8, 16] {
        let (y, c, x) = data(4096, kk, m, 44);
        b.case_units(&format!("K={kk}"), Some((4096 * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- engine comparison: rust vs artifact kernel suite ---
    let (y, c, x) = data(2048, 8, 512, 45);
    b.case_units("engine=rust", Some((2048 * 512) as f64), "cell", || {
        std::hint::black_box(compress_party(&y, &c, &x, 256, None));
    });
    let reference = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    b.case_units("engine=reference", Some((2048 * 512) as f64), "cell", || {
        std::hint::black_box(reference.compress_party(&y, &c, &x).unwrap());
    });
    match Engine::load("artifacts") {
        Ok(engine) => {
            b.case_units("engine=pjrt", Some((2048 * 512) as f64), "cell", || {
                std::hint::black_box(engine.compress_party(&y, &c, &x).unwrap());
            });
        }
        Err(e) => eprintln!("skipping PJRT engine case: {e:#}"),
    }

    // --- roofline reference: how fast can this machine merely READ the
    // data? (the paper's eq. 3: compress should be I/O-bound) ---
    let flat = x.data.clone();
    b.case_units("roofline-read", Some(flat.len() as f64), "cell", || {
        let s: f64 = flat.iter().sum();
        std::hint::black_box(s);
    });

    b.save_report();
    artifact_suite_rows();
    threaded_sweep_rows();
}

fn assert_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (g, w)) in a.iter().zip(b).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

/// E12 — threaded tiled compress → `BENCH_compress.json`: serial vs
/// threaded throughput over threads {1, 2, 4, 8} × shard width, every
/// threaded result asserted bit-identical to the serial bits, plus an
/// e2e sharded scan holding `compress_threads` result-neutral through
/// the full protocol. Speedup expectations only apply on multi-core
/// hosts — on a single-core runner the rows should merely not regress.
fn threaded_sweep_rows() {
    let mut b = Bench::new("compress-threaded");
    let (n, k, m) = (8192usize, 8usize, 1024usize);
    let (y, c, x) = data(n, k, m, 48);
    for &shard_w in &[64usize, 256] {
        let plan = ShardPlan::new(m, shard_w);
        let (yty_s, cty_s) = compress_yside(&y, &c, None, Some(1));
        let serial: Vec<VariantBlockStats> = plan
            .ranges()
            .map(|r| {
                compress_variant_block_opts(&y, &c, &x, r.j0, r.j1, shard_w, None, Some(1))
            })
            .collect();
        for &threads in &[1usize, 2, 4, 8] {
            b.case_units(
                &format!("shard_m={shard_w}/threads={threads}"),
                Some((n * m) as f64),
                "cell",
                || {
                    std::hint::black_box(compress_yside(&y, &c, None, Some(threads)));
                    for r in plan.ranges() {
                        std::hint::black_box(compress_variant_block_opts(
                            &y,
                            &c,
                            &x,
                            r.j0,
                            r.j1,
                            shard_w,
                            None,
                            Some(threads),
                        ));
                    }
                },
            );
            // the divergence gate: threaded bits must equal serial bits
            let (yty_p, cty_p) = compress_yside(&y, &c, None, Some(threads));
            let tag = format!("shard_m={shard_w} threads={threads}");
            assert_bits(&yty_p, &yty_s, &format!("{tag} yty"));
            assert_bits(&cty_p.data, &cty_s.data, &format!("{tag} cty"));
            for (r, s) in plan.ranges().zip(&serial) {
                let vb = compress_variant_block_opts(
                    &y,
                    &c,
                    &x,
                    r.j0,
                    r.j1,
                    shard_w,
                    None,
                    Some(threads),
                );
                let what = format!("{tag} shard {}..{}", r.j0, r.j1);
                assert_bits(&vb.xty.data, &s.xty.data, &format!("{what} xty"));
                assert_bits(&vb.xtx, &s.xtx, &format!("{what} xtx"));
                assert_bits(&vb.ctx.data, &s.ctx.data, &format!("{what} ctx"));
            }
        }
    }

    // e2e gate: a full sharded multi-party scan with compress_threads=4
    // reproduces the compress_threads=1 statistics bit-for-bit
    let cohort = generate_cohort(&CohortSpec::default_small(), 49);
    let run_with = |threads: usize| {
        let cfg = ScanConfig {
            backend: Backend::Masked,
            shard_m: 16,
            block_m: 32,
            compress_threads: Some(threads),
            ..Default::default()
        };
        run_multi_party_scan_t(&cohort, &cfg, Transport::InProc, 50).unwrap()
    };
    let serial = run_with(1);
    let threaded = run_with(4);
    for tt in 0..serial.output.t() {
        let (a, p) = (&serial.output.assoc[tt], &threaded.output.assoc[tt]);
        assert_bits(&p.beta, &a.beta, &format!("e2e trait {tt} beta"));
        assert_bits(&p.se, &a.se, &format!("e2e trait {tt} se"));
        assert_bits(&p.p, &a.p, &format!("e2e trait {tt} p"));
    }
    println!("e2e sharded scan: compress_threads=4 bit-identical to serial");

    b.save_report_to("BENCH_compress.json");
}

/// E10 — artifact kernel-suite rows: per-shard streaming dispatch vs a
/// whole-M pass, and trait batching (X-side work independent of T).
/// Written to `BENCH_artifact.json`; runs the reference executor, which
/// shares the suite's dispatch/padding machinery with the PJRT path.
fn artifact_suite_rows() {
    let mut b = Bench::new("artifact");
    let (n, k, m, shard_w) = (2048usize, 8usize, 1024usize, 256usize);
    let (y, c, x) = data(n, k, m, 46);

    let whole = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    b.case_units("whole-M", Some((n * m) as f64), "cell", || {
        std::hint::black_box(whole.compress_party(&y, &c, &x).unwrap());
    });

    let sharded = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    let plan = ShardPlan::new(m, shard_w);
    b.case_units("per-shard", Some((n * m) as f64), "cell", || {
        std::hint::black_box(sharded.compress_base(&y, &c).unwrap());
        for r in plan.ranges() {
            std::hint::black_box(
                sharded.compress_shard(&y, &c, &x, r.j0, r.j1).unwrap(),
            );
        }
    });
    // streaming keeps the resident block O(shard_w·N), not O(M·N)
    assert!(
        sharded.meter().peak_block_bytes() * 2 <= whole.meter().peak_block_bytes(),
        "per-shard peak {} not below whole-M peak {}",
        sharded.meter().peak_block_bytes(),
        whole.meter().peak_block_bytes()
    );

    // trait batching: the X-side pass count is one per call regardless
    // of T, and per-(variant·trait) cost falls as T grows
    let mut rng = Rng::new(47);
    for &t in &[1usize, 16] {
        let ys = Matrix::randn(n, t, &mut rng);
        let e = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
        b.case_units(&format!("T={t}"), Some((n * m * t) as f64), "cell·trait", || {
            std::hint::black_box(e.compress_shard(&ys, &c, &x, 0, m).unwrap());
        });
        // one metered dispatch = exactly one X-side pass, any T
        let probe = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
        probe.compress_shard(&ys, &c, &x, 0, m).unwrap();
        assert_eq!(probe.meter().xside_passes(), 1, "T={t}: one X-side pass per dispatch");
    }

    let report = b.json_lines();
    if let Err(e) = std::fs::write("BENCH_artifact.json", &report) {
        eprintln!("warn: could not write BENCH_artifact.json: {e}");
    } else {
        println!("report: BENCH_artifact.json");
    }
}
