//! E2 — compress-stage scaling: `O(N_p K²) + O(N K M / C)`.
//!
//! Rows regenerated:
//!   compress/N=...        runtime linear in N (fixed K, M)
//!   compress/threads=...  runtime ∝ 1/C (fixed N, K, M)
//!   compress/K=...        quadratic-in-K term at fixed N·M
//!   compress/engine=...   pure-Rust vs artifact kernel-suite paths
//!   roofline              bytes-read throughput vs machine copy bandwidth
//!
//! Plus the artifact-suite rows (E10) → `BENCH_artifact.json`:
//!   artifact/whole-M vs artifact/per-shard (streaming entry dispatch)
//!   artifact/T=...        trait batching: one X-side pass regardless of T
//!
//! `DASH_BENCH_QUICK=1` shrinks measurement windows ~10x.

use dash::linalg::Matrix;
use dash::runtime::{Engine, KernelMeter, ShapePolicy};
use dash::scan::{compress_party, ShardPlan};
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn data(n: usize, k: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    // genotype-like dosages: exercises the sparsity fast path realistically
    let mut x = Matrix::zeros(n, m);
    for v in x.data.iter_mut() {
        *v = rng.binomial(2, 0.3) as f64;
    }
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    (ys, c, x)
}

fn main() {
    let mut b = Bench::new("compress");
    let k = 8;
    let m = 1024;

    // --- scaling in N (expect ~linear) ---
    for &n in &[1024usize, 4096, 16384] {
        let (y, c, x) = data(n, k, m, 42);
        b.case_units(&format!("N={n}"), Some((n * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- scaling in threads (expect ∝ 1/C) ---
    let (y, c, x) = data(8192, k, m, 43);
    for &threads in &[1usize, 2, 4, 8] {
        b.case_units(
            &format!("threads={threads}"),
            Some((8192 * m) as f64),
            "cell",
            || {
                std::hint::black_box(compress_party(&y, &c, &x, 128, Some(threads)));
            },
        );
    }

    // --- scaling in K at fixed N, M ---
    for &kk in &[2usize, 8, 16] {
        let (y, c, x) = data(4096, kk, m, 44);
        b.case_units(&format!("K={kk}"), Some((4096 * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- engine comparison: rust vs artifact kernel suite ---
    let (y, c, x) = data(2048, 8, 512, 45);
    b.case_units("engine=rust", Some((2048 * 512) as f64), "cell", || {
        std::hint::black_box(compress_party(&y, &c, &x, 256, None));
    });
    let reference = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    b.case_units("engine=reference", Some((2048 * 512) as f64), "cell", || {
        std::hint::black_box(reference.compress_party(&y, &c, &x).unwrap());
    });
    match Engine::load("artifacts") {
        Ok(engine) => {
            b.case_units("engine=pjrt", Some((2048 * 512) as f64), "cell", || {
                std::hint::black_box(engine.compress_party(&y, &c, &x).unwrap());
            });
        }
        Err(e) => eprintln!("skipping PJRT engine case: {e:#}"),
    }

    // --- roofline reference: how fast can this machine merely READ the
    // data? (the paper's eq. 3: compress should be I/O-bound) ---
    let flat = x.data.clone();
    b.case_units("roofline-read", Some(flat.len() as f64), "cell", || {
        let s: f64 = flat.iter().sum();
        std::hint::black_box(s);
    });

    b.save_report();
    artifact_suite_rows();
}

/// E10 — artifact kernel-suite rows: per-shard streaming dispatch vs a
/// whole-M pass, and trait batching (X-side work independent of T).
/// Written to `BENCH_artifact.json`; runs the reference executor, which
/// shares the suite's dispatch/padding machinery with the PJRT path.
fn artifact_suite_rows() {
    let mut b = Bench::new("artifact");
    let (n, k, m, shard_w) = (2048usize, 8usize, 1024usize, 256usize);
    let (y, c, x) = data(n, k, m, 46);

    let whole = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    b.case_units("whole-M", Some((n * m) as f64), "cell", || {
        std::hint::black_box(whole.compress_party(&y, &c, &x).unwrap());
    });

    let sharded = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
    let plan = ShardPlan::new(m, shard_w);
    b.case_units("per-shard", Some((n * m) as f64), "cell", || {
        std::hint::black_box(sharded.compress_base(&y, &c).unwrap());
        for r in plan.ranges() {
            std::hint::black_box(
                sharded.compress_shard(&y, &c, &x, r.j0, r.j1).unwrap(),
            );
        }
    });
    // streaming keeps the resident block O(shard_w·N), not O(M·N)
    assert!(
        sharded.meter().peak_block_bytes() * 2 <= whole.meter().peak_block_bytes(),
        "per-shard peak {} not below whole-M peak {}",
        sharded.meter().peak_block_bytes(),
        whole.meter().peak_block_bytes()
    );

    // trait batching: the X-side pass count is one per call regardless
    // of T, and per-(variant·trait) cost falls as T grows
    let mut rng = Rng::new(47);
    for &t in &[1usize, 16] {
        let ys = Matrix::randn(n, t, &mut rng);
        let e = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
        b.case_units(&format!("T={t}"), Some((n * m * t) as f64), "cell·trait", || {
            std::hint::black_box(e.compress_shard(&ys, &c, &x, 0, m).unwrap());
        });
        // one metered dispatch = exactly one X-side pass, any T
        let probe = Engine::reference(ShapePolicy::default(), KernelMeter::new()).unwrap();
        probe.compress_shard(&ys, &c, &x, 0, m).unwrap();
        assert_eq!(probe.meter().xside_passes(), 1, "T={t}: one X-side pass per dispatch");
    }

    let report = b.json_lines();
    if let Err(e) = std::fs::write("BENCH_artifact.json", &report) {
        eprintln!("warn: could not write BENCH_artifact.json: {e}");
    } else {
        println!("report: BENCH_artifact.json");
    }
}
