//! E2 — compress-stage scaling: `O(N_p K²) + O(N K M / C)`.
//!
//! Rows regenerated:
//!   compress/N=...        runtime linear in N (fixed K, M)
//!   compress/threads=...  runtime ∝ 1/C (fixed N, K, M)
//!   compress/K=...        quadratic-in-K term at fixed N·M
//!   compress/engine=...   pure-Rust vs AOT-artifact path
//!   roofline              bytes-read throughput vs machine copy bandwidth
//!
//! `DASH_BENCH_QUICK=1` shrinks measurement windows ~10x.

use dash::linalg::Matrix;
use dash::scan::compress_party;
use dash::util::bench::Bench;
use dash::util::rng::Rng;

fn data(n: usize, k: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let mut c = Matrix::randn(n, k, &mut rng);
    for i in 0..n {
        c[(i, 0)] = 1.0;
    }
    // genotype-like dosages: exercises the sparsity fast path realistically
    let mut x = Matrix::zeros(n, m);
    for v in x.data.iter_mut() {
        *v = rng.binomial(2, 0.3) as f64;
    }
    let ys = Matrix::from_col((0..n).map(|_| rng.normal()).collect());
    (ys, c, x)
}

fn main() {
    let mut b = Bench::new("compress");
    let k = 8;
    let m = 1024;

    // --- scaling in N (expect ~linear) ---
    for &n in &[1024usize, 4096, 16384] {
        let (y, c, x) = data(n, k, m, 42);
        b.case_units(&format!("N={n}"), Some((n * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- scaling in threads (expect ∝ 1/C) ---
    let (y, c, x) = data(8192, k, m, 43);
    for &threads in &[1usize, 2, 4, 8] {
        b.case_units(
            &format!("threads={threads}"),
            Some((8192 * m) as f64),
            "cell",
            || {
                std::hint::black_box(compress_party(&y, &c, &x, 128, Some(threads)));
            },
        );
    }

    // --- scaling in K at fixed N, M ---
    for &kk in &[2usize, 8, 16] {
        let (y, c, x) = data(4096, kk, m, 44);
        b.case_units(&format!("K={kk}"), Some((4096 * m) as f64), "cell", || {
            std::hint::black_box(compress_party(&y, &c, &x, 256, None));
        });
    }

    // --- engine comparison: rust vs AOT artifacts ---
    let (y, c, x) = data(2048, 8, 512, 45);
    b.case_units("engine=rust", Some((2048 * 512) as f64), "cell", || {
        std::hint::black_box(compress_party(&y, &c, &x, 256, None));
    });
    match dash::runtime::Engine::load("artifacts") {
        Ok(engine) => {
            b.case_units("engine=artifacts", Some((2048 * 512) as f64), "cell", || {
                std::hint::black_box(engine.compress_party(&y, &c, &x).unwrap());
            });
        }
        Err(e) => eprintln!("skipping artifact engine case: {e:#}"),
    }

    // --- roofline reference: how fast can this machine merely READ the
    // data? (the paper's eq. 3: compress should be I/O-bound) ---
    let flat = x.data.clone();
    b.case_units("roofline-read", Some(flat.len() as f64), "cell", || {
        let s: f64 = flat.iter().sum();
        std::hint::black_box(s);
    });

    b.save_report();
}
