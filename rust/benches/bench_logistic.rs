//! Logistic (secure IRLS) workload economics: what does the iterative
//! null model cost on top of a linear scan over the same cohort?
//!
//! For each M in the sweep we run a case/control cohort (masked
//! backend, in-process transport) through `--glm logistic` and the same
//! cohort's quantitative twin through the linear scan, recording
//! iterations-to-converge, total/peak IRLS bytes, and wall time. The
//! two claims the protocol design makes, asserted at the end:
//!
//! * **Per-iteration traffic is `O(K²·T)`** — the peak IRLS round is
//!   the same number of bytes at every M (the null model never touches
//!   genotypes), and far below a linear per-shard round `O(K·shard_m·T)`.
//! * **The iteration count is a model property, not a scale property**
//!   — the deviance stop rule converges in a handful of Newton steps
//!   at every M.
//!
//! Output: human table + JSON lines written to `BENCH_logistic.json`.
//!
//! Run: `cargo bench --bench bench_logistic` (DASH_BENCH_QUICK=1 for a
//! reduced sweep).

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::{Glm, ScanConfig};
use dash::util::bench::Bench;
use dash::util::human_bytes;
use dash::util::json::Json;

fn spec(n_total: usize, parties: usize, m: usize, t: usize, binary: bool) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: t,
        n_causal: 5.min(m),
        effect_sd: 0.2,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: binary,
    }
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let parties = 3;
    let (n, t) = if quick { (300, 2) } else { (1200, 4) };
    let ms: &[usize] = if quick { &[128, 512] } else { &[256, 1024, 4096] };
    let shard_m = 128;

    let mut b = Bench::new("logistic");
    struct Row {
        m: usize,
        logistic_s: f64,
        linear_s: f64,
        irls_iters: usize,
        bytes_irls: u64,
        bytes_max_irls_round: u64,
        bytes_max_linear_round: u64,
        bytes_total: u64,
    }
    let mut rows: Vec<Row> = Vec::new();

    for &m in ms {
        eprintln!("generating cohorts: P={parties} N={n} M={m} T={t} ...");
        let cases = generate_cohort(&spec(n, parties, m, t, true), 96);
        let quant = generate_cohort(&spec(n, parties, m, t, false), 96);
        let log_cfg = ScanConfig {
            backend: Backend::Masked,
            shard_m,
            glm: Glm::Logistic,
            ..Default::default()
        };
        let lin_cfg =
            ScanConfig { backend: Backend::Masked, shard_m, ..Default::default() };
        let res = run_multi_party_scan_t(&cases, &log_cfg, Transport::InProc, 6).unwrap();
        let lin = run_multi_party_scan_t(&quant, &lin_cfg, Transport::InProc, 6).unwrap();
        let logistic_s = b
            .case_units(&format!("logistic M={m}"), Some((m * t) as f64), "assoc", || {
                std::hint::black_box(
                    run_multi_party_scan_t(&cases, &log_cfg, Transport::InProc, 6).unwrap(),
                );
            })
            .median_s;
        let linear_s = b
            .case_units(&format!("linear M={m}"), Some((m * t) as f64), "assoc", || {
                std::hint::black_box(
                    run_multi_party_scan_t(&quant, &lin_cfg, Transport::InProc, 6).unwrap(),
                );
            })
            .median_s;
        rows.push(Row {
            m,
            logistic_s,
            linear_s,
            irls_iters: res.metrics.irls_iters,
            bytes_irls: res.metrics.bytes_irls,
            bytes_max_irls_round: res.metrics.bytes_max_irls_round,
            bytes_max_linear_round: lin.metrics.bytes_max_round,
            bytes_total: res.metrics.bytes_total,
        });
    }

    println!("\nlogistic vs linear (P={parties}, N={n}, T={t}, masked, shard={shard_m}):");
    println!(
        "{:>7} {:>11} {:>9} {:>6} {:>12} {:>14} {:>14}",
        "M", "logistic_s", "linear_s", "iters", "irls_bytes", "peak_irls_rnd", "peak_lin_rnd"
    );
    for r in &rows {
        println!(
            "{:>7} {:>11.4} {:>9.4} {:>6} {:>12} {:>14} {:>14}",
            r.m,
            r.logistic_s,
            r.linear_s,
            r.irls_iters,
            human_bytes(r.bytes_irls),
            human_bytes(r.bytes_max_irls_round),
            human_bytes(r.bytes_max_linear_round)
        );
    }
    println!("(the IRLS loop never touches genotypes: its peak round is O(K²·T),");
    println!(" flat in M and far below a linear O(K·shard_m·T) shard round)");

    let mut report = b.json_lines();
    for r in &rows {
        let mut o = Json::obj();
        o.set("group", "logistic")
            .set("row", "irls")
            .set("m", r.m)
            .set("logistic_s", r.logistic_s)
            .set("linear_s", r.linear_s)
            .set("irls_iters", r.irls_iters)
            .set("bytes_irls", r.bytes_irls)
            .set("bytes_max_irls_round", r.bytes_max_irls_round)
            .set("bytes_max_linear_round", r.bytes_max_linear_round)
            .set("bytes_total", r.bytes_total);
        report.push_str(&o.to_string());
        report.push('\n');
    }
    if let Err(e) = std::fs::write("BENCH_logistic.json", &report) {
        eprintln!("warn: could not write BENCH_logistic.json: {e}");
    } else {
        println!("report: BENCH_logistic.json");
    }

    // The traffic claims, asserted.
    for pair in rows.windows(2) {
        assert_eq!(
            pair[0].bytes_max_irls_round, pair[1].bytes_max_irls_round,
            "peak IRLS round bytes must not scale with M (M={} vs M={})",
            pair[0].m, pair[1].m
        );
    }
    for r in &rows {
        assert!(
            r.bytes_max_irls_round < r.bytes_max_linear_round,
            "M={}: an IRLS round ({}) should cost less than a linear shard round ({})",
            r.m,
            r.bytes_max_irls_round,
            r.bytes_max_linear_round
        );
        assert!(
            (2..=25).contains(&r.irls_iters),
            "M={}: suspicious iteration count {}",
            r.m,
            r.irls_iters
        );
    }
}
