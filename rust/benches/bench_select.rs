//! SELECT-round cost vs a fresh re-scan baseline (E9).
//!
//! The tentpole claim: after the scan, each forward-stepwise round
//! costs one `O(lanes·H)` secure sum — independent of M — instead of
//! the `O((K+T)·M)` a fresh scan (the Chen et al. per-iteration shape)
//! would pay. Measured on real wire bytes and wall time:
//!
//! - `bytes_max_select_round` ≪ `bytes_max_round` (one scan shard
//!   round), and ≪ `bytes_total / shards`;
//! - the marginal wall time of `select_k` rounds is far below a scan.
//!
//! Output: human table + JSON lines → `BENCH_select.json`.
//!
//! Run: `cargo bench --bench bench_select` (DASH_BENCH_QUICK=1 for CI).

use dash::coordinator::{run_multi_party_scan_t, Transport};
use dash::gwas::{generate_cohort, CohortSpec};
use dash::mpc::Backend;
use dash::scan::ScanConfig;
use dash::util::bench::Bench;
use dash::util::human_bytes;
use dash::util::json::Json;

fn spec(n_total: usize, parties: usize, m: usize) -> CohortSpec {
    CohortSpec {
        party_sizes: vec![n_total / parties; parties],
        m_variants: m,
        n_traits: 1,
        n_causal: 8.min(m),
        effect_sd: 0.5,
        fst: 0.05,
        party_admixture: (0..parties).map(|i| i as f64 / (parties - 1) as f64).collect(),
        ancestry_effect: 0.4,
        batch_effect_sd: 0.1,
        n_pcs: 2,
        noise_sd: 1.0,
        binary_traits: false,
    }
}

fn cfg(select_k: usize) -> ScanConfig {
    ScanConfig {
        backend: Backend::Masked,
        shard_m: 512,
        select_k,
        // permissive stop rule so every bench round actually runs
        select_alpha: 0.9,
        select_candidates: 32,
        ..Default::default()
    }
}

fn main() {
    let quick = std::env::var("DASH_BENCH_QUICK").ok().as_deref() == Some("1");
    let parties = 3;
    let (n, m) = if quick { (600, 4096) } else { (1500, 16384) };
    let k_rounds = 3usize;

    eprintln!("generating cohort: P={parties} N={n} M={m} ...");
    let cohort = generate_cohort(&spec(n, parties, m), 91);

    // one instrumented run for the communication shape
    let probe = run_multi_party_scan_t(&cohort, &cfg(k_rounds), Transport::InProc, 6).unwrap();
    assert_eq!(
        probe.metrics.select_rounds, k_rounds,
        "permissive stop rule should fill all rounds"
    );
    let sel = probe.select.as_ref().expect("select output");

    let mut b = Bench::new("select");
    let scan_only = b
        .case_units("scan-only", Some(m as f64), "var", || {
            std::hint::black_box(
                run_multi_party_scan_t(&cohort, &cfg(0), Transport::InProc, 6).unwrap(),
            );
        })
        .median_s;
    let scan_select = b
        .case_units(&format!("scan+select-k{k_rounds}"), Some(m as f64), "var", || {
            std::hint::black_box(
                run_multi_party_scan_t(&cohort, &cfg(k_rounds), Transport::InProc, 6).unwrap(),
            );
        })
        .median_s;
    let marginal_round_s = (scan_select - scan_only).max(0.0) / k_rounds as f64;

    println!("\nSELECT cost vs fresh-scan baseline (P={parties}, N={n}, M={m}, masked):");
    println!("  selected: {:?}", sel.selected(0));
    println!(
        "  scan bytes_total {}   peak scan round {}   peak SELECT round {}",
        human_bytes(probe.metrics.bytes_total),
        human_bytes(probe.metrics.bytes_max_round),
        human_bytes(probe.metrics.bytes_max_select_round),
    );
    println!(
        "  SELECT phase bytes {}   marginal wall per round {:.2} ms (scan {:.2} ms)",
        human_bytes(probe.metrics.bytes_select),
        marginal_round_s * 1e3,
        scan_only * 1e3,
    );
    println!("  (a SELECT round must be ≪ a fresh scan: bytes AND wall time)");

    let mut report = b.json_lines();
    let mut o = Json::obj();
    o.set("group", "select")
        .set("row", "comm")
        .set("m", m)
        .set("select_k", k_rounds)
        .set("candidates", sel.candidates.len())
        .set("bytes_total", probe.metrics.bytes_total)
        .set("bytes_max_round", probe.metrics.bytes_max_round)
        .set("bytes_max_select_round", probe.metrics.bytes_max_select_round)
        .set("bytes_select", probe.metrics.bytes_select)
        .set("scan_only_s", scan_only)
        .set("scan_select_s", scan_select)
        .set("marginal_round_s", marginal_round_s);
    report.push_str(&o.to_string());
    report.push('\n');
    if let Err(e) = std::fs::write("BENCH_select.json", &report) {
        eprintln!("warn: could not write BENCH_select.json: {e}");
    } else {
        println!("report: BENCH_select.json");
    }

    // E9 assertions: a SELECT round r+1 is cheaper than a fresh scan on
    // every axis the protocol can measure.
    assert!(
        probe.metrics.bytes_max_select_round * 8 < probe.metrics.bytes_max_round,
        "select round bytes {} not ≪ scan round bytes {}",
        probe.metrics.bytes_max_select_round,
        probe.metrics.bytes_max_round
    );
    assert!(
        probe.metrics.bytes_select * 8 < probe.metrics.bytes_total,
        "select phase bytes {} not ≪ scan total {}",
        probe.metrics.bytes_select,
        probe.metrics.bytes_total
    );
    assert!(
        marginal_round_s < scan_only,
        "marginal select round {marginal_round_s}s not cheaper than a fresh scan {scan_only}s"
    );
}
